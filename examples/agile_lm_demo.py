"""AgileNN split serving on an LM backbone: train the token-level
extractor + local head + remote backbone jointly with the skewness losses,
then report the offload payload and local/remote/combined accuracy.

  PYTHONPATH=src python examples/agile_lm_demo.py --arch qwen2-0.5b --steps 120
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.base import AgileSpec
from repro.core.agile_lm import (
    agile_lm_forward,
    agile_lm_loss,
    extract_token_features,
    init_agile_lm_params,
    offload_payload_bits,
)
from repro.core.agile_lm import _token_importance
from repro.core.skewness import achieved_skewness, disorder_rate
from repro.data.synthetic import SyntheticTokens, TokenDatasetSpec
from repro.optim.adamw import adamw_init, adamw_update


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ASSIGNED_ARCHS)
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--k", type=int, default=6)
    ap.add_argument("--rho", type=float, default=0.7)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config(args.arch).reduced(),
        agile=AgileSpec(enabled=True, extractor_channels=32, k=args.k,
                        rho=args.rho, lam=0.4, ig_steps=4))
    data = SyntheticTokens(TokenDatasetSpec(vocab=32, seq_len=12, n_modes=2))
    params = init_agile_lm_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)

    @jax.jit
    def step(p, o, toks):
        (loss, m), g = jax.value_and_grad(
            lambda pp: agile_lm_loss(cfg, pp, toks[:, :-1], toks[:, -1]),
            has_aux=True)(p)
        p, o = adamw_update(p, g, o, lr=5e-3, weight_decay=0.0)
        return p, o, loss, m

    for i in range(args.steps):
        toks = jnp.asarray(data.batch(16, seed=i))
        params, opt, loss, m = step(params, opt, toks)
        if i % 30 == 0:
            print(f"step {i:4d} loss {float(loss):.3f} "
                  f"acc {float(m['accuracy']):.3f} "
                  f"skew_loss {float(m['loss_skewness']):.4f} "
                  f"alpha {float(m['alpha']):.3f}")

    # evaluation
    toks = jnp.asarray(data.batch(128, seed=777_777))
    tokens, labels = toks[:, :-1], toks[:, -1]
    logits, internals = agile_lm_forward(cfg, params, tokens, train=False)
    acc = float(jnp.mean((jnp.argmax(logits, -1) == labels)))
    acc_local = float(jnp.mean((jnp.argmax(internals["local_logits"], -1) == labels)))
    acc_remote = float(jnp.mean((jnp.argmax(internals["remote_logits"], -1) == labels)))
    feats = extract_token_features(params, tokens)
    imp = _token_importance(cfg, params["reference"], feats, labels, steps=4)
    print(f"\ncombined acc {acc:.3f} | local-only {acc_local:.3f} | "
          f"remote-only {acc_remote:.3f}")
    print(f"achieved skewness {float(achieved_skewness(imp, cfg.agile.k)):.3f} "
          f"(target {cfg.agile.rho}) | disorder rate "
          f"{float(disorder_rate(imp, cfg.agile.k)):.3f}")
    bits = offload_payload_bits(cfg, params, tokens[:1])
    print(f"offload payload per request: {bits} bits "
          f"({(32 - cfg.agile.k) * 32} fp32 bits uncompressed -> "
          f"{bits / ((32 - cfg.agile.k) * 32):.2f}x)")


if __name__ == "__main__":
    main()
