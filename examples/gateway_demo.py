"""A 32-client weak-device fleet driving the offload gateway on CPU.

Simulates the paper's real deployment shape: every client runs the
AgileNN local path (extractor + top-k split + quantize + LZW) on an
STM32-class device model, ships its feature payload over a WiFi /
narrowband / lossy-WiFi link mix, and the gateway batches arrivals into
fixed-width Remote-NN calls.  Run twice — static rate, then adaptive rate
against a latency SLO — and compare the per-link latency, payload and
device-energy accounting.

  PYTHONPATH=src python examples/gateway_demo.py --clients 32 --slo-ms 30
"""
import argparse

import jax

from repro.configs.agilenn_cifar import gateway_demo_config
from repro.core.agile import init_agile_params
from repro.serve.gateway import (
    Fleet, GatewayConfig, OffloadGateway, mixed_fleet)


def run_once(cfg, params, args, slo_ms):
    specs = mixed_fleet(args.clients, n_requests=args.requests,
                        slo_ms=slo_ms)
    fleet = Fleet(cfg, params, specs, seed=args.seed)
    gw = OffloadGateway(cfg, params, fleet,
                        GatewayConfig(batch_width=args.batch_width))
    return fleet, gw.run()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch-width", type=int, default=8)
    ap.add_argument("--slo-ms", type=float, default=30.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = gateway_demo_config()
    params = init_agile_params(cfg, jax.random.PRNGKey(args.seed))

    print(f"== static rate ({args.clients} clients x {args.requests} reqs, "
          f"pool width {args.batch_width}) ==")
    _, static = run_once(cfg, params, args, None)
    for k, v in static.summary().items():
        print(f"  {k}: {v}")

    print(f"== adaptive rate (SLO {args.slo_ms:g} ms) ==")
    fleet, adaptive = run_once(cfg, params, args, args.slo_ms)
    for k, v in adaptive.summary().items():
        print(f"  {k}: {v}")
    print("  final rate-ladder level per client:",
          [c.controller.level for c in fleet.clients])

    s, a = static.summary(), adaptive.summary()
    print(f"adaptive vs static: payload {a['payload_bytes_mean']:.1f}B vs "
          f"{s['payload_bytes_mean']:.1f}B, device energy "
          f"{a['device_energy_mj']:.3f}mJ vs {s['device_energy_mj']:.3f}mJ")


if __name__ == "__main__":
    main()
