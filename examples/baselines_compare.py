"""Reproduce the paper's comparison story in one script: train AgileNN and
all four baselines on the same synthetic data, print the Figure-16-style
latency/accuracy/energy table.

  PYTHONPATH=src python examples/baselines_compare.py
"""
import numpy as np

from benchmarks.common import trained_baselines, trained_system
from benchmarks.paper_figures import (
    fig16_latency_accuracy,
    fig19_energy,
    tab2_transmission,
)


def main():
    print("training AgileNN + baselines on synthetic data (cached) ...")
    trained_system()
    trained_baselines()
    print(f"\n{'name':42s} {'value':>12s}  derived")
    for fn in (fig16_latency_accuracy, tab2_transmission, fig19_energy):
        for name, value, derived in fn():
            v = f"{value:.4g}" if isinstance(value, float) else str(value)
            print(f"{name:42s} {v:>12s}  {derived}")


if __name__ == "__main__":
    main()
