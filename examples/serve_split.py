"""Serve a small LLM backbone with batched requests through the serving
engine: prefill a batch of prompts, then decode tokens step by step (the
paper's Remote-NN role on the pod; reduced config so it runs on CPU).

  PYTHONPATH=src python examples/serve_split.py --arch mixtral-8x7b --tokens 16
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import backbone as bb


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ASSIGNED_ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    key = jax.random.PRNGKey(0)
    params = bb.init_params(cfg, key)

    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab)}
    if cfg.vlm is not None:
        batch["patches"] = jax.random.normal(
            key, (args.batch, cfg.vlm.n_patches, cfg.vlm.vision_dim))
    if cfg.encdec is not None:
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.encdec.n_frames, cfg.d_model))

    print(f"== prefill ({args.arch} reduced, B={args.batch}, "
          f"T={args.prompt_len}) ==")
    t0 = time.time()
    logits, cache, total_T = bb.prefill(
        cfg, params, batch, max_len=args.prompt_len + args.tokens + 8)
    print(f"prefill: {time.time() - t0:.2f}s, cache leaves: "
          f"{len(jax.tree_util.tree_leaves(cache))}")

    decode = jax.jit(lambda p, t, c, n: bb.decode_step(cfg, p, t, c, n))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    cl = total_T
    t0 = time.time()
    for _ in range(args.tokens):
        logits, cache = decode(params, tok, cache, cl)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
        cl += 1
    dt = time.time() - t0
    gen = np.asarray(jnp.concatenate(out_tokens, axis=1))
    print(f"decoded {args.tokens} tokens x {args.batch} reqs in {dt:.2f}s "
          f"({args.tokens * args.batch / dt:.1f} tok/s on CPU)")
    print("generations (greedy, untrained weights):")
    for b in range(args.batch):
        print(f"  req{b}: {gen[b].tolist()}")


if __name__ == "__main__":
    main()
