"""End-to-end training driver: train a reduced backbone LM on synthetic
Markov token data for a few hundred steps with AdamW + cosine schedule,
checkpointing every N steps.

  PYTHONPATH=src python examples/train_backbone.py --arch llama3.2-1b --steps 200
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.io import save_checkpoint
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.data.synthetic import SyntheticTokens, TokenDatasetSpec
from repro.models import backbone as bb
from repro.nn.module import param_count
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.schedules import clip_by_global_norm, cosine_schedule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ASSIGNED_ARCHS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt.npz")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    # effective vocab 64 (< model vocab) keeps the Markov table learnable
    # in a few hundred CPU steps
    data = SyntheticTokens(TokenDatasetSpec(vocab=min(64, cfg.vocab),
                                            seq_len=args.seq + 1, n_modes=4))
    key = jax.random.PRNGKey(0)
    params = bb.init_params(cfg, key)
    opt = adamw_init(params)
    print(f"{cfg.name}: {param_count(params) / 1e6:.2f}M params")

    @jax.jit
    def step(p, o, tokens, lr):
        batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

        def loss_fn(pp):
            return bb.forward_loss(cfg, pp, batch)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(p)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        p, o = adamw_update(p, grads, o, lr=lr, weight_decay=0.01)
        return p, o, loss, gnorm

    t0 = time.time()
    for i in range(args.steps):
        toks = jnp.asarray(data.batch(args.batch, seed=i))
        lr = float(cosine_schedule(i, base_lr=args.lr, warmup=20,
                                   total=args.steps))
        params, opt, loss, gnorm = step(params, opt, toks, lr)
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(loss):.4f} "
                  f"gnorm {float(gnorm):.2f} lr {lr:.2e} "
                  f"({(time.time() - t0):.1f}s)")
        if args.ckpt and (i + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt, params)
            print(f"  checkpoint -> {args.ckpt}")
    print("done.")


if __name__ == "__main__":
    main()
