"""Quickstart: train AgileNN end-to-end (stages A-D) on synthetic
CIFAR-like data, then run the deployment-path offload inference with full
cost accounting.

  PYTHONPATH=src python examples/quickstart.py [--steps 150]
"""
import argparse

import jax

from repro.configs.agilenn_cifar import AgileNNConfig
from repro.configs.base import AgileSpec
from repro.serve.offload import energy_per_inference, run_offload_inference
from repro.train.agile_pipeline import run_full_pipeline


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--pretrain-steps", type=int, default=80)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--rho", type=float, default=0.8)
    ap.add_argument("--xai", choices=("ig", "saliency"), default="ig")
    args = ap.parse_args()

    cfg = AgileNNConfig(
        image_size=16, remote_width=24, remote_blocks=2,
        reference_width=32, reference_blocks=3,
        agile=AgileSpec(enabled=True, extractor_channels=24, k=args.k,
                        rho=args.rho, lam=0.3, ig_steps=4))

    print("== AgileNN pipeline (stages A-D) ==")
    params, ref, report, history, data = run_full_pipeline(
        cfg, pretrain_steps=args.pretrain_steps, joint_steps=args.steps,
        batch_size=32, xai_method=args.xai, log_every=25)
    print(f"report: {report}")

    print("== deployment-path inference ==")
    images, labels = data.batch(16, seed=123_456)
    preds, cost = run_offload_inference(cfg, params, images)
    acc = float((preds == labels).mean())
    print(f"accuracy           : {acc:.3f}")
    for k, v in cost.as_dict.items():
        print(f"{k:18s}: {v:.4f}" if isinstance(v, float) else f"{k:18s}: {v}")
    print(f"energy_mJ          : {energy_per_inference(cfg, cost) * 1e3:.4f}")


if __name__ == "__main__":
    main()
