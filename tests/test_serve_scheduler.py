"""Continuous-batching scheduler invariants.

Every correctness claim is checked against the one-request-at-a-time
reference (the engine's equal-length fast path, which PR 1 proved equal
to a hand-rolled prefill+decode loop): bucket padding must not leak into
outputs, evict/inject must preserve the surviving slots' cache contents,
and no request may be starved by other buckets.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import backbone as bb
from repro.serve.engine import Request, ServeEngine
from repro.serve.scheduler import (
    ContinuousScheduler,
    SchedulerConfig,
    supports_continuous_batching,
)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def system():
    cfg = get_config("qwen2-0.5b").reduced()
    params = bb.init_params(cfg, KEY)
    return cfg, params


def _engine(cfg, params, **sched_kw):
    kw = dict(buckets=(8, 16, 32), max_slots=4, prefill_group=2, chunk=4)
    kw.update(sched_kw)
    return ServeEngine(cfg, params, max_len=64,
                       scheduler=SchedulerConfig(**kw))


def _reference(eng, req) -> np.ndarray:
    """One-request-at-a-time greedy decode via the fast path."""
    return eng.generate([req])[0].tokens


# ------------------------------------------------------- acceptance check --


def test_mixed_queue_matches_per_request_greedy(system):
    """24 mixed-length requests ({8, 16, 32} prompts) through the
    scheduler produce exactly the tokens per-request decoding produces."""
    cfg, params = system
    eng = _engine(cfg, params)
    rng = np.random.RandomState(0)
    lengths = [8, 16, 32] * 8
    rng.shuffle(lengths)
    reqs = [Request(tokens=rng.randint(0, cfg.vocab, L), max_new_tokens=4)
            for L in lengths]
    outs = eng.generate(reqs)
    assert len(outs) == 24
    for req, got in zip(reqs, outs):
        np.testing.assert_array_equal(got.tokens, _reference(eng, req))


def test_bucket_padding_never_leaks(system):
    """Off-bucket prompts (5 -> bucket 8, 11 -> 16, 27 -> 32) decode to
    the same tokens as the unpadded per-request reference."""
    cfg, params = system
    eng = _engine(cfg, params)
    rng = np.random.RandomState(1)
    reqs = [Request(tokens=rng.randint(0, cfg.vocab, L), max_new_tokens=5)
            for L in (5, 11, 27, 5)]
    outs = eng.generate(reqs)
    for req, got in zip(reqs, outs):
        assert len(got.tokens) == 5
        np.testing.assert_array_equal(got.tokens, _reference(eng, req))


def test_evict_inject_preserves_slot_cache(system):
    """A 2-slot pool over 6 staggered-budget requests forces several
    evict/inject cycles mid-decode; surviving slots must keep decoding as
    if alone (their cache rows untouched by neighbours swapping)."""
    cfg, params = system
    eng = _engine(cfg, params, max_slots=2, prefill_group=1, chunk=2)
    rng = np.random.RandomState(2)
    lens = [8, 16, 8, 32, 16, 8]
    buds = [2, 9, 5, 3, 7, 4]          # finish at different segments
    reqs = [Request(tokens=rng.randint(0, cfg.vocab, L), max_new_tokens=n)
            for L, n in zip(lens, buds)]
    outs = eng.generate(reqs)
    for req, got in zip(reqs, outs):
        assert len(got.tokens) == req.max_new_tokens
        np.testing.assert_array_equal(got.tokens, _reference(eng, req))


def test_no_request_starved_across_buckets(system):
    """FIFO head-bucket admission: a lone bucket-32 request buried in a
    stream of bucket-8 arrivals still completes (and every rid is
    returned exactly once)."""
    cfg, params = system
    sched = ContinuousScheduler(
        cfg, params, max_len=64,
        sched=SchedulerConfig(buckets=(8, 16, 32), max_slots=2,
                              prefill_group=2, chunk=2))
    rng = np.random.RandomState(3)
    rids = []
    for i in range(10):
        L = 32 if i == 4 else 8
        rids.append(sched.submit(Request(
            tokens=rng.randint(0, cfg.vocab, L), max_new_tokens=3)))
    outs = sched.run()
    assert sorted(outs) == sorted(rids)
    for rid in rids:
        assert len(outs[rid].tokens) == 3


# -------------------------------------------------- in-graph per-request --


def test_per_request_eos_and_temperature_in_pool(system):
    """EOS ids and sampling temperatures are per-slot, in-graph: a greedy
    row keeps its reference tokens while a sampled row runs at its own
    temperature, and an EOS hit stops only that request."""
    cfg, params = system
    eng = _engine(cfg, params)
    rng = np.random.RandomState(4)
    p8 = rng.randint(0, cfg.vocab, 8)
    p16 = rng.randint(0, cfg.vocab, 16)
    ref8 = _reference(eng, Request(tokens=p8, max_new_tokens=6))
    eos = int(ref8[2])
    stop = int(np.argmax(ref8 == eos)) + 1   # first greedy eos hit

    outs = eng.generate([
        Request(tokens=p8, max_new_tokens=6, eos_id=eos),
        Request(tokens=p16, max_new_tokens=6, temperature=1.3),
        Request(tokens=p8, max_new_tokens=6),
    ])
    np.testing.assert_array_equal(outs[0].tokens, ref8[:stop])  # stops at eos
    assert len(outs[1].tokens) == 6
    assert outs[1].tokens.min() >= 0 and outs[1].tokens.max() < cfg.vocab
    np.testing.assert_array_equal(outs[2].tokens, ref8)       # full budget


def test_overlap_matches_serialized(system):
    """The pipelined scheduler (chunk dispatched before the host blocks,
    drain one round behind, admissions double-buffered) produces exactly
    the serialized scheduler's greedy tokens on a mixed queue with
    chunked-prefill admissions in it."""
    cfg, params = system
    rng = np.random.RandomState(6)
    reqs = [Request(tokens=rng.randint(0, cfg.vocab, L), max_new_tokens=4)
            for L in (8, 16, 100, 5, 27, 16, 8, 120)]

    def tokens_with(overlap):
        sched = ContinuousScheduler(
            cfg, params, max_len=192,
            sched=SchedulerConfig(buckets=(8, 16, 32, 64, 128),
                                  max_slots=4, prefill_group=2, chunk=4,
                                  prefill_segment=32, overlap=overlap))
        rids = [sched.submit(r) for r in reqs]
        outs = sched.run()
        assert sorted(outs) == sorted(rids)
        return [outs[r].tokens for r in rids]

    for a, b in zip(tokens_with(True), tokens_with(False)):
        np.testing.assert_array_equal(a, b)


def test_mesh_engine_routes_equal_lengths_through_scheduler(system):
    """A meshed engine must not silently drop its sharding: equal-length
    batches go through the (sharded) scheduler, not the single-device
    fast path, and still match the per-request reference."""
    from repro.launch.mesh import make_serving_mesh
    cfg, params = system
    eng = ServeEngine(cfg, params, max_len=64,
                      mesh=make_serving_mesh(data=1, model=1),
                      scheduler=SchedulerConfig(buckets=(8, 16, 32),
                                                max_slots=2, prefill_group=2,
                                                chunk=4))
    ref = ServeEngine(cfg, params, max_len=64)
    rng = np.random.RandomState(9)
    reqs = [Request(tokens=rng.randint(0, cfg.vocab, 16), max_new_tokens=4)
            for _ in range(3)]
    outs = eng.generate(reqs)
    assert eng._sched is not None          # scheduler path, not fast path
    for req, got in zip(reqs, outs):
        np.testing.assert_array_equal(got.tokens,
                                      ref.generate([req])[0].tokens)


# ------------------------------------------------------------- gating -----


def test_unsupported_arch_falls_back_to_length_groups():
    """MoE/hybrid/absolute-position archs are gated out of the scheduler;
    mixed-length generate still works via equal-length grouping."""
    cfg = get_config("mixtral-8x7b").reduced()
    assert not supports_continuous_batching(cfg)
    params = bb.init_params(cfg, KEY)
    eng = ServeEngine(cfg, params, max_len=64)
    rng = np.random.RandomState(5)
    reqs = [Request(tokens=rng.randint(0, cfg.vocab, L), max_new_tokens=2)
            for L in (8, 12, 8)]
    outs = eng.generate(reqs)
    assert [len(c.tokens) for c in outs] == [2, 2, 2]
    # grouping preserves request order: re-running one request alone
    # reproduces its grouped tokens
    np.testing.assert_array_equal(outs[1].tokens,
                                  eng.generate([reqs[1]])[0].tokens)


def test_scheduler_rejects_unsupported_arch():
    cfg = get_config("jamba-1.5-large-398b").reduced()
    assert not supports_continuous_batching(cfg)
    with pytest.raises(AssertionError):
        ContinuousScheduler(cfg, bb.init_params(cfg, KEY), max_len=32)
