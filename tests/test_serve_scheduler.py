"""Continuous-batching scheduler invariants.

Every correctness claim is checked against the one-request-at-a-time
reference (the engine's equal-length fast path, which PR 1 proved equal
to a hand-rolled prefill+decode loop): bucket padding must not leak into
outputs, evict/inject must preserve the surviving slots' cache contents,
and no request may be starved by other buckets.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import backbone as bb
from repro.serve.engine import Request, ServeEngine
from repro.serve.scheduler import (
    ContinuousScheduler,
    SchedulerConfig,
    supports_continuous_batching,
)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def system():
    cfg = get_config("qwen2-0.5b").reduced()
    params = bb.init_params(cfg, KEY)
    return cfg, params


def _engine(cfg, params, **sched_kw):
    kw = dict(buckets=(8, 16, 32), max_slots=4, prefill_group=2, chunk=4)
    kw.update(sched_kw)
    return ServeEngine(cfg, params, max_len=64,
                       scheduler=SchedulerConfig(**kw))


def _reference(eng, req) -> np.ndarray:
    """One-request-at-a-time greedy decode via the fast path."""
    return eng.generate([req])[0].tokens


# ------------------------------------------------------- acceptance check --


def test_mixed_queue_matches_per_request_greedy(system):
    """24 mixed-length requests ({8, 16, 32} prompts) through the
    scheduler produce exactly the tokens per-request decoding produces."""
    cfg, params = system
    eng = _engine(cfg, params)
    rng = np.random.RandomState(0)
    lengths = [8, 16, 32] * 8
    rng.shuffle(lengths)
    reqs = [Request(tokens=rng.randint(0, cfg.vocab, L), max_new_tokens=4)
            for L in lengths]
    outs = eng.generate(reqs)
    assert len(outs) == 24
    for req, got in zip(reqs, outs):
        np.testing.assert_array_equal(got.tokens, _reference(eng, req))


def test_bucket_padding_never_leaks(system):
    """Off-bucket prompts (5 -> bucket 8, 11 -> 16, 27 -> 32) decode to
    the same tokens as the unpadded per-request reference."""
    cfg, params = system
    eng = _engine(cfg, params)
    rng = np.random.RandomState(1)
    reqs = [Request(tokens=rng.randint(0, cfg.vocab, L), max_new_tokens=5)
            for L in (5, 11, 27, 5)]
    outs = eng.generate(reqs)
    for req, got in zip(reqs, outs):
        assert len(got.tokens) == 5
        np.testing.assert_array_equal(got.tokens, _reference(eng, req))


def test_evict_inject_preserves_slot_cache(system):
    """A 2-slot pool over 6 staggered-budget requests forces several
    evict/inject cycles mid-decode; surviving slots must keep decoding as
    if alone (their cache rows untouched by neighbours swapping)."""
    cfg, params = system
    eng = _engine(cfg, params, max_slots=2, prefill_group=1, chunk=2)
    rng = np.random.RandomState(2)
    lens = [8, 16, 8, 32, 16, 8]
    buds = [2, 9, 5, 3, 7, 4]          # finish at different segments
    reqs = [Request(tokens=rng.randint(0, cfg.vocab, L), max_new_tokens=n)
            for L, n in zip(lens, buds)]
    outs = eng.generate(reqs)
    for req, got in zip(reqs, outs):
        assert len(got.tokens) == req.max_new_tokens
        np.testing.assert_array_equal(got.tokens, _reference(eng, req))


def test_no_request_starved_across_buckets(system):
    """FIFO head-bucket admission: a lone bucket-32 request buried in a
    stream of bucket-8 arrivals still completes (and every rid is
    returned exactly once)."""
    cfg, params = system
    sched = ContinuousScheduler(
        cfg, params, max_len=64,
        sched=SchedulerConfig(buckets=(8, 16, 32), max_slots=2,
                              prefill_group=2, chunk=2))
    rng = np.random.RandomState(3)
    rids = []
    for i in range(10):
        L = 32 if i == 4 else 8
        rids.append(sched.submit(Request(
            tokens=rng.randint(0, cfg.vocab, L), max_new_tokens=3)))
    outs = sched.run()
    assert sorted(outs) == sorted(rids)
    for rid in rids:
        assert len(outs[rid].tokens) == 3


# -------------------------------------------------- in-graph per-request --


def test_per_request_eos_and_temperature_in_pool(system):
    """EOS ids and sampling temperatures are per-slot, in-graph: a greedy
    row keeps its reference tokens while a sampled row runs at its own
    temperature, and an EOS hit stops only that request."""
    cfg, params = system
    eng = _engine(cfg, params)
    rng = np.random.RandomState(4)
    p8 = rng.randint(0, cfg.vocab, 8)
    p16 = rng.randint(0, cfg.vocab, 16)
    ref8 = _reference(eng, Request(tokens=p8, max_new_tokens=6))
    eos = int(ref8[2])
    stop = int(np.argmax(ref8 == eos)) + 1   # first greedy eos hit

    outs = eng.generate([
        Request(tokens=p8, max_new_tokens=6, eos_id=eos),
        Request(tokens=p16, max_new_tokens=6, temperature=1.3),
        Request(tokens=p8, max_new_tokens=6),
    ])
    np.testing.assert_array_equal(outs[0].tokens, ref8[:stop])  # stops at eos
    assert len(outs[1].tokens) == 6
    assert outs[1].tokens.min() >= 0 and outs[1].tokens.max() < cfg.vocab
    np.testing.assert_array_equal(outs[2].tokens, ref8)       # full budget


def test_overlap_matches_serialized(system):
    """The pipelined scheduler (chunk dispatched before the host blocks,
    drain one round behind, admissions double-buffered) produces exactly
    the serialized scheduler's greedy tokens on a mixed queue with
    chunked-prefill admissions in it."""
    cfg, params = system
    rng = np.random.RandomState(6)
    reqs = [Request(tokens=rng.randint(0, cfg.vocab, L), max_new_tokens=4)
            for L in (8, 16, 100, 5, 27, 16, 8, 120)]

    def tokens_with(overlap):
        sched = ContinuousScheduler(
            cfg, params, max_len=192,
            sched=SchedulerConfig(buckets=(8, 16, 32, 64, 128),
                                  max_slots=4, prefill_group=2, chunk=4,
                                  prefill_segment=32, overlap=overlap))
        rids = [sched.submit(r) for r in reqs]
        outs = sched.run()
        assert sorted(outs) == sorted(rids)
        return [outs[r].tokens for r in rids]

    for a, b in zip(tokens_with(True), tokens_with(False)):
        np.testing.assert_array_equal(a, b)


def test_mesh_engine_routes_equal_lengths_through_scheduler(system):
    """A meshed engine must not silently drop its sharding: equal-length
    batches go through the (sharded) scheduler, not the single-device
    fast path, and still match the per-request reference."""
    from repro.launch.mesh import make_serving_mesh
    cfg, params = system
    eng = ServeEngine(cfg, params, max_len=64,
                      mesh=make_serving_mesh(data=1, model=1),
                      scheduler=SchedulerConfig(buckets=(8, 16, 32),
                                                max_slots=2, prefill_group=2,
                                                chunk=4))
    ref = ServeEngine(cfg, params, max_len=64)
    rng = np.random.RandomState(9)
    reqs = [Request(tokens=rng.randint(0, cfg.vocab, 16), max_new_tokens=4)
            for _ in range(3)]
    outs = eng.generate(reqs)
    assert eng._sched is not None          # scheduler path, not fast path
    for req, got in zip(reqs, outs):
        np.testing.assert_array_equal(got.tokens,
                                      ref.generate([req])[0].tokens)


# ------------------------------------------------------------- gating -----


def test_unsupported_arch_falls_back_to_length_groups():
    """MoE/hybrid/absolute-position archs are gated out of the scheduler;
    mixed-length generate still works via equal-length grouping."""
    cfg = get_config("mixtral-8x7b").reduced()
    assert not supports_continuous_batching(cfg)
    params = bb.init_params(cfg, KEY)
    eng = ServeEngine(cfg, params, max_len=64)
    rng = np.random.RandomState(5)
    reqs = [Request(tokens=rng.randint(0, cfg.vocab, L), max_new_tokens=2)
            for L in (8, 12, 8)]
    outs = eng.generate(reqs)
    assert [len(c.tokens) for c in outs] == [2, 2, 2]
    # grouping preserves request order: re-running one request alone
    # reproduces its grouped tokens
    np.testing.assert_array_equal(outs[1].tokens,
                                  eng.generate([reqs[1]])[0].tokens)


def test_scheduler_rejects_unsupported_arch():
    cfg = get_config("jamba-1.5-large-398b").reduced()
    assert not supports_continuous_batching(cfg)
    with pytest.raises(AssertionError):
        ContinuousScheduler(cfg, bb.init_params(cfg, KEY), max_len=32)


# ------------------------------------------------- deadlines and faults ---


class _Clock:
    """Deterministic wall clock: every read advances by one tick."""

    def __init__(self, tick: float):
        self.t = 0.0
        self.tick = tick

    def __call__(self) -> float:
        self.t += self.tick
        return self.t


def _fault_sched(cfg, params, *, overlap=False, clock=None, faults=None,
                 **kw):
    base = dict(buckets=(8, 16, 32), max_slots=2, prefill_group=1, chunk=2,
                prefill_segment=8, overlap=overlap)
    base.update(kw)
    return ContinuousScheduler(cfg, params, max_len=64,
                               sched=SchedulerConfig(**base),
                               clock=clock, faults=faults)


def test_deadline_evicts_queued_and_pooled(system):
    """An expired queued request resolves empty; a pooled request evicts
    between chunks with the tokens generated so far — a prefix of its
    reference decode — and a deadline-free neighbour is untouched."""
    cfg, params = system
    eng = _engine(cfg, params)
    rng = np.random.RandomState(7)
    pa = rng.randint(0, cfg.vocab, 8)
    pb = rng.randint(0, cfg.vocab, 8)
    pc = rng.randint(0, cfg.vocab, 8)
    ref_a = _reference(eng, Request(tokens=pa, max_new_tokens=40))
    ref_b = _reference(eng, Request(tokens=pb, max_new_tokens=4))

    sched = _fault_sched(cfg, params, clock=_Clock(0.01))
    ra = sched.submit(Request(tokens=pa, max_new_tokens=40, deadline_s=0.055))
    rb = sched.submit(Request(tokens=pb, max_new_tokens=4))
    rc = sched.submit(Request(tokens=pc, max_new_tokens=4, deadline_s=0.001))
    outs = sched.run()
    assert sorted(outs) == sorted([ra, rb, rc])
    assert outs[rc].timed_out and len(outs[rc].tokens) == 0
    assert outs[ra].timed_out
    assert 0 < len(outs[ra].tokens) < 40
    np.testing.assert_array_equal(outs[ra].tokens,
                                  ref_a[:len(outs[ra].tokens)])
    assert not outs[rb].timed_out
    np.testing.assert_array_equal(outs[rb].tokens, ref_b)
    assert not sched._slots.any_occupied() and not sched._deadlines


def test_deadline_aborts_staging_and_slot_is_reused(system):
    """A chunked-prefill admission whose deadline lapses mid-staging
    frees its claimed slot; a later request reuses the slot and decodes
    its reference tokens."""
    cfg, params = system
    eng = _engine(cfg, params)
    rng = np.random.RandomState(8)
    long_p = rng.randint(0, cfg.vocab, 32)
    short_p = rng.randint(0, cfg.vocab, 8)
    ref_short = _reference(eng, Request(tokens=short_p, max_new_tokens=3))

    sched = _fault_sched(cfg, params, clock=_Clock(0.01), max_slots=1)
    rl = sched.submit(Request(tokens=long_p, max_new_tokens=4,
                              deadline_s=0.015))
    rs = sched.submit(Request(tokens=short_p, max_new_tokens=3))
    outs = sched.run()
    assert outs[rl].timed_out and len(outs[rl].tokens) == 0
    np.testing.assert_array_equal(outs[rs].tokens, ref_short)
    assert not sched._slots.any_occupied()


@pytest.mark.parametrize("overlap", [False, True])
def test_stalled_pool_exits_via_deadline_eviction(system, overlap):
    """Acceptance: a permanently stalled decode pool cannot hang run() —
    every deadline-carrying request leaves through deadline eviction."""
    from repro.serve.faults import FaultInjector, SlotPoolStall
    cfg, params = system
    rng = np.random.RandomState(9)
    sched = _fault_sched(cfg, params, overlap=overlap, clock=_Clock(0.01),
                         faults=FaultInjector((SlotPoolStall(),)))
    rids = [sched.submit(Request(tokens=rng.randint(0, cfg.vocab, 8),
                                 max_new_tokens=4, deadline_s=0.04))
            for _ in range(4)]
    outs = sched.run()
    assert sorted(outs) == sorted(rids)
    assert all(outs[r].timed_out for r in rids)
    assert not sched._slots.any_occupied()


def test_bounded_stall_only_delays_decode(system):
    """A stall window without deadlines delays rounds but changes no
    tokens — requests decode their exact reference output after it."""
    from repro.serve.faults import FaultInjector, SlotPoolStall
    cfg, params = system
    eng = _engine(cfg, params)
    rng = np.random.RandomState(10)
    reqs = [Request(tokens=rng.randint(0, cfg.vocab, L), max_new_tokens=4)
            for L in (8, 16, 8)]
    sched = _fault_sched(cfg, params,
                         faults=FaultInjector((SlotPoolStall(0, 3),)))
    rids = [sched.submit(r) for r in reqs]
    outs = sched.run()
    for r, rid in zip(reqs, rids):
        assert not outs[rid].timed_out
        np.testing.assert_array_equal(outs[rid].tokens, _reference(eng, r))


def test_idle_injector_and_generous_deadlines_keep_tokens(system):
    """Acceptance (bit-identity): an empty fault schedule and deadlines
    that never fire leave the scheduler's greedy tokens unchanged, in
    both overlap modes."""
    from repro.serve.faults import FaultInjector
    cfg, params = system
    rng = np.random.RandomState(11)
    prompts = [rng.randint(0, cfg.vocab, L) for L in (8, 16, 32, 8)]

    def tokens(deadline, faults, overlap):
        sched = _fault_sched(cfg, params, overlap=overlap, faults=faults)
        rids = [sched.submit(Request(tokens=p, max_new_tokens=4,
                                     deadline_s=deadline))
                for p in prompts]
        outs = sched.run()
        assert not any(outs[r].timed_out for r in rids)
        return [outs[r].tokens for r in rids]

    for overlap in (False, True):
        plain = tokens(None, None, overlap)
        faulted = tokens(1e6, FaultInjector(()), overlap)
        for a, b in zip(plain, faulted):
            np.testing.assert_array_equal(a, b)


def test_deadline_churn_preserves_slot_invariants(system):
    """Satellite: repeated deadline-evict/readmit cycles on a width-2
    pool never leak or double-assign a slot, and every rid resolves
    exactly once (overlap mode's stale snapshot must not complete a
    readmitted slot's new occupant)."""
    cfg, params = system
    rng = np.random.RandomState(12)
    sched = _fault_sched(cfg, params, overlap=True, clock=_Clock(0.005))
    rids = []
    for i in range(12):
        rids.append(sched.submit(Request(
            tokens=rng.randint(0, cfg.vocab, 8), max_new_tokens=30,
            deadline_s=0.03 + 0.015 * (i % 4))))
    outs = sched.run()
    assert sorted(outs) == sorted(rids)       # exactly once each
    assert all(outs[r].timed_out for r in rids)
    assert not sched._slots.any_occupied() and not sched._deadlines
    assert not sched._staging and sched._pending is None


def test_long_prompts_bucket_at_page_granularity(system):
    """Satellite (compile-cache bound): prompts above every configured
    bucket round up to the next page_size multiple instead of bucketing
    at their raw length — distinct long lengths share one prefill
    compilation (watched through the telemetry compile counter, not a
    private cache poke), and tokens still match the reference."""
    from repro.serve.telemetry import Telemetry
    cfg, params = system
    eng = _engine(cfg, params)
    tel = Telemetry(enabled=True)
    sched = ContinuousScheduler(
        cfg, params, max_len=64,
        sched=SchedulerConfig(buckets=(8, 16), max_slots=4,
                              prefill_group=2, chunk=4, page_size=16,
                              prefill_segment=0),   # group path only
        telemetry=tel)
    assert sched._bucket_of(33) == 48
    assert sched._bucket_of(41) == 48
    assert sched._bucket_of(63) == 64               # capped at max_len
    rng = np.random.RandomState(14)
    reqs = [Request(tokens=rng.randint(0, cfg.vocab, L), max_new_tokens=4)
            for L in (33, 37, 41, 45)]
    rids = [sched.submit(r) for r in reqs]
    outs = sched.run()
    assert tel.compile_count("sched.prefill") == 1, \
        "four long lengths in one page bucket must share one compilation"
    assert tel.counter("jit.sched.prefill.compiles", shape="bucket48").n == 1
    for req, rid in zip(reqs, rids):
        np.testing.assert_array_equal(outs[rid].tokens,
                                      _reference(eng, req))


def test_steady_state_decode_zero_recompiles(system):
    """Satellite (telemetry compile counter): once a first drain has paid
    the per-bucket prefill and fixed-width decode-chunk compiles, an
    identically shaped second workload must record zero new jit
    compilations — the steady-state guarantee the CI gate watches."""
    from repro.serve.telemetry import Telemetry
    cfg, params = system
    tel = Telemetry(enabled=True)
    sched = ContinuousScheduler(
        cfg, params, max_len=64,
        sched=SchedulerConfig(buckets=(8, 16), max_slots=4,
                              prefill_group=2, chunk=4),
        telemetry=tel)
    rng = np.random.RandomState(21)

    def batch():
        for L in (8, 16, 8, 16):
            sched.submit(Request(tokens=rng.randint(0, cfg.vocab, L),
                                 max_new_tokens=3))
        sched.run()

    batch()                                 # pays every compile
    warm = tel.compile_count("sched")
    assert warm >= 3                        # two prefill buckets + chunk
    batch()                                 # same shapes: steady state
    assert tel.compile_count("sched") == warm, \
        "steady-state decode recompiled"


def test_stale_snapshot_skips_readmitted_slot(system):
    """Satellite: under overlap, a slot deadline-evicted between a
    chunk's dispatch and its `_drain_pending`, then re-admitted, must
    not be completed from the stale snapshot (`p["rids"][i] == rid`):
    the new occupant decodes its own full reference and no slot leaks."""
    cfg, params = system
    eng = _engine(cfg, params)
    rng = np.random.RandomState(15)
    pa = rng.randint(0, cfg.vocab, 8)
    pb = rng.randint(0, cfg.vocab, 8)
    ref_a = _reference(eng, Request(tokens=pa, max_new_tokens=40))
    ref_b = _reference(eng, Request(tokens=pb, max_new_tokens=4))

    sched = _fault_sched(cfg, params, overlap=True, max_slots=1,
                         clock=_Clock(0.005))
    ra = sched.submit(Request(tokens=pa, max_new_tokens=40,
                              deadline_s=0.06))
    rb = sched.submit(Request(tokens=pb, max_new_tokens=4))
    outs = sched.run()
    assert sorted(outs) == sorted([ra, rb])   # each resolved exactly once
    # the evictee kept its own partial decode (a prefix of its reference)
    assert outs[ra].timed_out and 0 < len(outs[ra].tokens) < 40
    np.testing.assert_array_equal(outs[ra].tokens,
                                  ref_a[:len(outs[ra].tokens)])
    # the slot's new occupant was admitted while ra's snapshot was still
    # pending; that snapshot must not have completed it early or with the
    # evictee's buffer
    assert not outs[rb].timed_out
    np.testing.assert_array_equal(outs[rb].tokens, ref_b)
    assert not sched._slots.any_occupied() and sched._pending is None
    assert not sched._deadlines and not sched._staging


def test_engine_routes_deadlines_through_scheduler(system):
    """Equal-length requests carrying deadlines leave the fast path (it
    cannot evict) and still produce the fast path's tokens when the
    deadline never fires."""
    cfg, params = system
    eng = _engine(cfg, params)
    rng = np.random.RandomState(13)
    p = rng.randint(0, cfg.vocab, 16)
    ref = _reference(eng, Request(tokens=p, max_new_tokens=4))
    outs = eng.generate([Request(tokens=p, max_new_tokens=4,
                                 deadline_s=1e6)])
    assert eng._sched is not None             # scheduler path was taken
    assert not outs[0].timed_out
    np.testing.assert_array_equal(outs[0].tokens, ref)
