"""Mamba / mLSTM / sLSTM: chunked-parallel vs sequential oracles, and
full-sequence vs step-decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.ssm import (
    mamba_apply,
    mamba_decode_apply,
    mamba_decode_init_state,
    mamba_init,
    mamba_reference,
)
from repro.nn.xlstm import (
    mlstm_apply,
    mlstm_chunked,
    mlstm_decode_apply,
    mlstm_decode_init_state,
    mlstm_init,
    mlstm_sequential,
    slstm_apply,
    slstm_decode_apply,
    slstm_decode_init_state,
    slstm_init,
)

KEY = jax.random.PRNGKey(2)


@pytest.mark.parametrize("B,T,d,chunk", [(2, 19, 32, 8), (1, 16, 16, 16), (1, 7, 8, 4)])
def test_mamba_chunked_vs_sequential(B, T, d, chunk):
    p = mamba_init(KEY, d)
    x = 0.5 * jax.random.normal(KEY, (B, T, d))
    y = mamba_apply(p, x, chunk=chunk)
    yr = mamba_reference(p, x)
    np.testing.assert_allclose(y, yr, atol=2e-4, rtol=2e-4)


def test_mamba_prefill_state_matches_decode():
    B, T, d = 1, 12, 16
    p = mamba_init(KEY, d)
    x = 0.5 * jax.random.normal(KEY, (B, T + 3, d))
    _, state = mamba_apply(p, x[:, :T], return_state=True)
    # continue decoding and compare with full run
    full = mamba_reference(p, x)
    for t in range(T, T + 3):
        y, state = mamba_decode_apply(p, x[:, t:t + 1], state)
        np.testing.assert_allclose(y, full[:, t:t + 1], atol=3e-4, rtol=3e-4)


@pytest.mark.parametrize("B,T,H,D,chunk", [(2, 17, 2, 8, 8), (1, 33, 4, 16, 16)])
def test_mlstm_chunked_vs_sequential(B, T, H, D, chunk):
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, T, H, D))
    v = jax.random.normal(ks[2], (B, T, H, D))
    li = jax.random.normal(ks[3], (B, T, H))
    lf = jax.nn.log_sigmoid(3.0 + jax.random.normal(ks[4], (B, T, H)))
    y, _ = mlstm_chunked(q, k, v, li, lf, chunk=chunk)
    yr = mlstm_sequential(q, k, v, li, lf)
    np.testing.assert_allclose(y, yr, atol=2e-4, rtol=2e-4)


def test_mlstm_full_vs_decode():
    B, T, d, H = 2, 11, 32, 4
    p = mlstm_init(KEY, d, H)
    x = 0.5 * jax.random.normal(KEY, (B, T, d))
    y = mlstm_apply(p, x, n_heads=H, chunk=4)
    st = mlstm_decode_init_state(B, H, d // H)
    ys = []
    for t in range(T):
        yt, st = mlstm_decode_apply(p, x[:, t:t + 1], st, n_heads=H)
        ys.append(yt)
    np.testing.assert_allclose(jnp.concatenate(ys, 1), y, atol=5e-4, rtol=5e-4)


def test_slstm_full_vs_decode():
    B, T, d, H = 2, 9, 32, 4
    p = slstm_init(KEY, d, H)
    x = 0.5 * jax.random.normal(KEY, (B, T, d))
    y = slstm_apply(p, x, n_heads=H)
    st = slstm_decode_init_state(B, d)
    ys = []
    for t in range(T):
        yt, st = slstm_decode_apply(p, x[:, t:t + 1], st, n_heads=H)
        ys.append(yt)
    np.testing.assert_allclose(jnp.concatenate(ys, 1), y, atol=5e-5, rtol=5e-5)


def test_mamba_no_future_leakage():
    """Causality: perturbing x[t] must not change y[<t]."""
    B, T, d = 1, 10, 16
    p = mamba_init(KEY, d)
    x = 0.5 * jax.random.normal(KEY, (B, T, d))
    y1 = mamba_apply(p, x, chunk=4)
    x2 = x.at[:, 7].add(10.0)
    y2 = mamba_apply(p, x2, chunk=4)
    np.testing.assert_allclose(y1[:, :7], y2[:, :7], atol=1e-6)
    assert float(jnp.abs(y1[:, 7:] - y2[:, 7:]).max()) > 1e-3
