"""Grouped MoE dispatch vs the per-expert-loop oracle + properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.nn.moe import _pick_group_size, moe_apply, moe_init, moe_reference

KEY = jax.random.PRNGKey(1)


@pytest.mark.parametrize("B,T,gs,topk", [(2, 8, 4096, 2), (2, 8, 4, 2),
                                         (3, 7, 4096, 1), (1, 16, 8, 3)])
def test_moe_matches_reference_dropless(B, T, gs, topk):
    p = moe_init(KEY, 16, 32, 4)
    x = jax.random.normal(KEY, (B, T, 16))
    y, aux = moe_apply(p, x, top_k=topk, capacity_factor=8.0, group_size=gs)
    yr = moe_reference(p, x, top_k=topk)
    np.testing.assert_allclose(y, yr, atol=1e-4, rtol=1e-4)
    assert float(aux["dropped_fraction"]) == 0.0


def test_moe_capacity_drops_tokens():
    p = moe_init(KEY, 16, 32, 4)
    x = jax.random.normal(KEY, (4, 16, 16))
    _, aux = moe_apply(p, x, top_k=2, capacity_factor=0.5)
    assert float(aux["dropped_fraction"]) > 0.0


def test_moe_load_balance_loss_bounds():
    """E * sum(f * p) >= 1 with equality at perfect balance."""
    p = moe_init(KEY, 16, 32, 4)
    x = jax.random.normal(KEY, (4, 16, 16))
    _, aux = moe_apply(p, x, top_k=2, capacity_factor=8.0)
    assert float(aux["load_balance_loss"]) >= 0.99


@given(n=st.integers(1, 4096), target=st.sampled_from([256, 1024, 4096]))
@settings(max_examples=50, deadline=None)
def test_pick_group_size_divides(n, target):
    s = _pick_group_size(n, target)
    assert n % s == 0
    assert s <= max(target, n)


def test_moe_grad_flows_to_all_parts():
    p = moe_init(KEY, 8, 16, 4)
    x = jax.random.normal(KEY, (2, 8, 8))

    def loss(pp):
        y, aux = moe_apply(pp, x, top_k=2, capacity_factor=8.0)
        return jnp.sum(y ** 2) + aux["load_balance_loss"]

    g = jax.grad(loss)(p)
    for name in ("router", "gate", "up", "down"):
        leaf = g[name]["w"] if isinstance(g[name], dict) else g[name]
        assert float(jnp.abs(leaf).max()) > 0.0, name
