"""Offload runtime + device cost model + baselines (construct/train/cost)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.agilenn_cifar import AgileNNConfig
from repro.configs.base import AgileSpec
from repro.core.agile import init_agile_params
from repro.core.baselines import (
    deepcod_cost,
    deepcod_init,
    deepcod_loss,
    edge_only_cost,
    mcunet_cost,
    mcunet_init,
    mcunet_macs,
    spinn_cost,
    spinn_init,
    spinn_loss,
)
from repro.data.synthetic import ImageDatasetSpec, SyntheticImages
from repro.serve.device_model import DeviceModel, mcu_memory_model
from repro.serve.offload import (
    energy_per_inference,
    measure_payload,
    remote_nn_macs,
    run_offload_inference,
)

KEY = jax.random.PRNGKey(9)
CFG = AgileNNConfig(image_size=16, remote_width=16, remote_blocks=2,
                    reference_width=16, reference_blocks=2,
                    agile=AgileSpec(enabled=True, extractor_channels=24, k=5,
                                    rho=0.8, lam=0.3, ig_steps=2))


def test_device_model_latency_monotonic_in_bandwidth():
    fast = DeviceModel(link_bps=6e6)
    slow = DeviceModel(link_bps=270e3)
    assert slow.tx_time(1000) > fast.tx_time(1000)
    assert fast.compute_time(1e6) == slow.compute_time(1e6)


def test_offload_inference_cost_breakdown():
    params = init_agile_params(CFG, KEY)
    x = jax.random.normal(KEY, (4, 16, 16, 3))
    preds, cost = run_offload_inference(CFG, params, x)
    assert preds.shape == (4,)
    d = cost.as_dict
    assert d["payload_bytes"] > 0
    assert d["end_to_end_ms"] > 0
    assert d["local_macs"] > 0
    e = energy_per_inference(CFG, cost)
    assert e > 0


def test_payload_smaller_than_raw_features():
    params = init_agile_params(CFG, KEY)
    x = jax.random.normal(KEY, (4, 16, 16, 3))
    payload, idx = measure_payload(CFG, params, x)
    raw_bytes = idx.size * 4  # float32 features would be 4 bytes each
    assert payload < raw_bytes


def test_mcunet_local_only_no_tx():
    cost = mcunet_cost(CFG)
    assert cost.tx_s == 0.0 and cost.payload_bytes == 0.0
    assert cost.local_compute_s > 0
    assert mcunet_macs(CFG) > 0


def test_edge_only_no_local_compute():
    x = np.random.RandomState(0).randn(2, 16, 16, 3).astype(np.float32)
    cost = edge_only_cost(CFG, x, remote_macs=1e6)
    assert cost.local_macs == 0.0
    assert cost.payload_bytes > 0


def test_deepcod_and_spinn_train_one_step():
    data = SyntheticImages(ImageDatasetSpec(image_size=16, noise=0.3))
    images, labels = data.batch(8, seed=0)
    dp = deepcod_init(KEY, CFG)
    (loss, metrics), grads = jax.value_and_grad(deepcod_loss, has_aux=True)(
        dp, images, labels)
    assert np.isfinite(float(loss))
    cost = deepcod_cost(CFG, dp, images, remote_macs=remote_nn_macs(CFG, 4))
    assert cost.payload_bytes > 0

    sp = spinn_init(KEY, CFG)
    (loss, metrics), grads = jax.value_and_grad(spinn_loss, has_aux=True)(
        sp, images, labels)
    assert np.isfinite(float(loss))
    cost = spinn_cost(CFG, sp, images, remote_macs=remote_nn_macs(CFG, 4))
    assert cost.local_macs > 0


def test_mcu_memory_model():
    mem = mcu_memory_model(100_000, 50_000)
    assert mem["flash_bytes"] == 100_000
    assert mem["sram_bytes"] == 50_000


def test_agilenn_beats_mcunet_latency():
    """The paper's headline: AgileNN end-to-end latency is far below
    local-only inference on the same device model."""
    params = init_agile_params(CFG, KEY)
    x = jax.random.normal(KEY, (4, 16, 16, 3))
    _, agile_cost = run_offload_inference(CFG, params, x)
    local_cost = mcunet_cost(CFG, width=32, blocks=4)
    assert agile_cost.local_compute_s < local_cost.local_compute_s
