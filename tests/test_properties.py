"""Hypothesis property tests over system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.nn.attention import flash_attention, reference_attention
from repro.core.xai import channel_importance
from repro.compress.lzw import (
    lzw_decode,
    lzw_encode,
    pack_indices,
    pack_indices_batch,
)
from repro.compress.quantize import dequantize, hard_indices, quantizer_init

KEY = jax.random.PRNGKey(11)


@given(T=st.integers(4, 24), Hkv=st.sampled_from([1, 2]),
       G=st.sampled_from([1, 2, 3]), D=st.sampled_from([4, 8]),
       qb=st.sampled_from([4, 8]), kb=st.sampled_from([4, 8]))
@settings(max_examples=20, deadline=None)
def test_flash_attention_blocking_invariance(T, Hkv, G, D, qb, kb):
    """Output must not depend on the block decomposition."""
    Hq = Hkv * G
    ks = jax.random.split(jax.random.PRNGKey(T * 131 + Hq), 3)
    q = jax.random.normal(ks[0], (1, T, Hq, D))
    k = jax.random.normal(ks[1], (1, T, Hkv, D))
    v = jax.random.normal(ks[2], (1, T, Hkv, D))
    a = flash_attention(q, k, v, q_block=qb, kv_block=kb)
    b = reference_attention(q, k, v)
    np.testing.assert_allclose(a, b, atol=3e-5, rtol=3e-5)


@given(T=st.integers(2, 16))
@settings(max_examples=15, deadline=None)
def test_attention_rows_are_convex_combinations(T):
    """Causal attention output at position t lies in the convex hull of
    v[:t+1] — per-dim bounds check."""
    ks = jax.random.split(jax.random.PRNGKey(T), 3)
    q = jax.random.normal(ks[0], (1, T, 2, 4))
    k = jax.random.normal(ks[1], (1, T, 2, 4))
    v = jax.random.normal(ks[2], (1, T, 2, 4))
    out = reference_attention(q, k, v, causal=True)   # Hq == Hkv (G=1)
    for t in range(T):
        lo = jnp.min(v[:, :t + 1], axis=1)    # (1, H, D)
        hi = jnp.max(v[:, :t + 1], axis=1)
        o = out[:, t]                          # (1, H, D)
        assert bool(jnp.all(o >= lo - 1e-4))
        assert bool(jnp.all(o <= hi + 1e-4))


@given(st.integers(2, 64))
@settings(max_examples=20, deadline=None)
def test_channel_importance_is_distribution(C):
    x = jax.random.uniform(jax.random.PRNGKey(C), (3, 5, 5, C)) + 1e-3
    imp = channel_importance(x)
    np.testing.assert_allclose(np.asarray(jnp.sum(imp, -1)), 1.0, rtol=1e-5)
    assert bool(jnp.all(imp >= 0))


@given(st.one_of(
    st.binary(max_size=1024),
    # low-entropy payloads (the quantized-index regime LZW targets)
    st.lists(st.integers(0, 3), max_size=2048).map(bytes)))
@settings(max_examples=40, deadline=None)
def test_lzw_round_trip(data):
    """decode(encode(x)) == x for arbitrary and low-entropy byte strings."""
    assert lzw_decode(lzw_encode(data)) == data


@given(B=st.integers(1, 9), n=st.integers(1, 80),
       bits=st.sampled_from([1, 2, 3, 4, 5, 8]),
       seed=st.integers(0, 2 ** 16))
@settings(max_examples=40, deadline=None)
def test_pack_indices_batch_matches_per_sample(B, n, bits, seed):
    """The vectorized batch packer is byte-identical to packing each
    sample alone, across ragged batch/row sizes and every bit width."""
    idx = np.random.RandomState(seed).randint(0, 2 ** bits, size=(B, n))
    got = pack_indices_batch(idx, bits)
    assert len(got) == B
    for b in range(B):
        assert got[b] == pack_indices(idx[b], bits)


@given(st.lists(st.floats(-10, 10), min_size=1, max_size=64),
       st.sampled_from([2, 4, 8, 16]))
@settings(max_examples=30, deadline=None)
def test_quantizer_idempotent(vals, L):
    """Quantizing a dequantized value is a fixed point."""
    q = quantizer_init(L, -4, 4)
    x = jnp.asarray(vals, jnp.float32)
    once = dequantize(q, hard_indices(q, x))
    twice = dequantize(q, hard_indices(q, once))
    np.testing.assert_allclose(once, twice)
