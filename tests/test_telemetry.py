"""Telemetry contracts: closed-form histogram quantiles, span
bookkeeping on a virtual clock, exporter well-formedness, and — the hard
one — the no-subscriber bit-identity guarantee: with telemetry disabled
(or enabled: instrumentation only *reads*) the scheduler's greedy tokens
and the gateway's seeded fault traces are bit-identical to an
uninstrumented run, and the disabled path performs zero clock reads."""
import json
import math

import jax
import numpy as np
import pytest

from repro.serve.telemetry import (
    DEFAULT_BOUNDS,
    Histogram,
    MetricsRegistry,
    Telemetry,
    Tracer,
    exponential,
)

KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------- histograms --


def test_histogram_closed_form_quantiles_hand_computed():
    """bounds (1,2,4), samples {0.5, 1.5, 3, 5}: the cumulative walk plus
    linear interpolation gives p0=min, p50=2.0 (top of bucket 1),
    p100=max — each verifiable by hand."""
    h = Histogram("t", bounds=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 3.0, 5.0):
        h.observe(v)
    assert h.counts == [1, 1, 1, 1]         # one per bucket + overflow
    assert h.count == 4 and h.total == 10.0
    assert h.percentile(0) == 0.5           # tightened to observed min
    assert h.percentile(50) == 2.0          # rank 2 tops out bucket 1
    assert h.percentile(100) == 5.0         # overflow tightened to max
    assert h.mean == 2.5


def test_histogram_percentiles_monotone_and_bounded():
    rng = np.random.RandomState(3)
    h = Histogram("t")
    xs = rng.lognormal(mean=-3.0, sigma=2.0, size=500)
    for v in xs:
        h.observe(float(v))
    qs = [h.percentile(q) for q in (0, 10, 25, 50, 75, 90, 99, 100)]
    assert qs == sorted(qs)
    assert qs[0] == xs.min() and qs[-1] == xs.max()
    assert math.isnan(Histogram("empty").p50())


def test_exact_histogram_matches_np_percentile_bitwise():
    """The bench helpers' percentile dedup must not move row values:
    exact mode defers to np.percentile on the retained samples."""
    rng = np.random.RandomState(7)
    xs = rng.uniform(0.0, 50.0, size=137)
    h = Histogram.exact()
    for v in xs:
        h.observe(float(v))
    for q in (0, 12.5, 50, 99, 100):
        assert h.percentile(q) == float(np.percentile(xs, q))


def test_pctl_helper_is_np_percentile():
    from benchmarks.common import pctl
    xs = np.random.RandomState(9).normal(size=64)
    assert pctl(xs, 99) == float(np.percentile(xs, 99))
    assert pctl(list(xs), 50) == float(np.percentile(xs, 50))


def test_histogram_rejects_unsorted_bounds():
    with pytest.raises(ValueError):
        Histogram("bad", bounds=(2.0, 1.0))
    assert exponential(1.0, 2.0, 4) == (1.0, 2.0, 4.0, 8.0)


# ----------------------------------------------------------- registry --


def test_registry_create_or_get_and_label_identity():
    m = MetricsRegistry()
    a = m.counter("x", path="a")
    assert m.counter("x", path="a") is a            # same labels: same cell
    b = m.counter("x", path="b")
    assert b is not a
    a.inc(2)
    d = m.to_dict()
    assert d["x{path=a}"] == 2 and d["x{path=b}"] == 0


def test_prometheus_text_shape():
    m = MetricsRegistry()
    m.counter("req.count", status="ok").inc(3)
    m.gauge("pool.occupancy").set(5)
    h = m.histogram("lat_s", bounds=(0.1, 1.0))
    for v in (0.05, 0.5, 2.0):
        h.observe(v)
    text = m.prometheus_text()
    assert '# TYPE req_count counter' in text
    assert 'req_count{status="ok"} 3' in text
    assert 'pool_occupancy 5' in text
    # cumulative bucket counts: <=0.1 -> 1, <=1.0 -> 2, +Inf -> 3
    assert 'lat_s_bucket{le="0.1"} 1' in text
    assert 'lat_s_bucket{le="1"} 2' in text
    assert 'lat_s_bucket{le="+Inf"} 3' in text
    assert 'lat_s_count 3' in text


# ------------------------------------------------------------ tracing --


def test_span_nesting_on_virtual_clock():
    """Wall spans stamped off an injected virtual clock nest by interval
    containment and land on the caller's timeline exactly."""
    from repro.serve.frontend import VirtualClock
    vc = VirtualClock()
    tel = Telemetry(enabled=True, clock=vc)
    with tel.span("outer", track="sched"):
        vc.now = 1.0
        with tel.span("inner", track="sched", round=3):
            vc.now = 2.0
        vc.now = 4.0
    inner, outer = tel.trace.spans            # close order: inner first
    assert (inner.name, inner.t0, inner.t1) == ("inner", 1.0, 2.0)
    assert (outer.name, outer.t0, outer.t1) == ("outer", 0.0, 4.0)
    assert outer.t0 <= inner.t0 and inner.t1 <= outer.t1
    assert inner.args == {"round": 3} and inner.dur == 1.0


def test_chrome_trace_well_formed(tmp_path):
    tr = Tracer()
    tr.add("b", 2e-3, 3e-3, track="gw")
    tr.add("a", 1e-3, 4e-3, track="sched", cat="sched", round=1)
    out = tmp_path / "trace.json"
    tr.write(str(out))
    doc = json.loads(out.read_text())
    assert doc["displayTimeUnit"] == "ms"
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert {m["args"]["name"] for m in meta} == {"gw", "sched"}
    assert [e["name"] for e in xs] == ["a", "b"]      # sorted by t0
    a = xs[0]
    assert a["ts"] == pytest.approx(1e3) and a["dur"] == pytest.approx(3e3)
    assert a["args"] == {"round": 1}
    tids = {m["args"]["name"]: m["tid"] for m in meta}
    assert xs[0]["tid"] == tids["sched"] and xs[1]["tid"] == tids["gw"]


def test_disabled_telemetry_never_reads_clock():
    """The no-subscriber contract at the facade: a disabled Telemetry
    must not touch its clock (spans are free no-ops)."""
    def boom():
        raise AssertionError("disabled telemetry read the clock")
    tel = Telemetry(enabled=False, clock=boom)
    with tel.span("x", track="t"):
        pass
    assert tel.trace.spans == []
    with pytest.raises(AssertionError):     # sanity: enabled DOES read it
        with Telemetry(enabled=True, clock=boom).span("x"):
            pass


# ---------------------------------------- bit-identity: scheduler -----


@pytest.fixture(scope="module")
def lm_system():
    from repro.configs import get_config
    from repro.models import backbone as bb
    cfg = get_config("qwen2-0.5b").reduced()
    return cfg, bb.init_params(cfg, KEY)


def _sched_tokens(cfg, params, telemetry=None):
    from repro.serve.engine import Request
    from repro.serve.scheduler import ContinuousScheduler, SchedulerConfig
    sched = ContinuousScheduler(
        cfg, params, max_len=48,
        sched=SchedulerConfig(buckets=(8, 16), max_slots=2,
                              prefill_group=2, chunk=2),
        telemetry=telemetry)
    rng = np.random.RandomState(4)
    rids = [sched.submit(Request(tokens=rng.randint(0, cfg.vocab, L),
                                 max_new_tokens=4))
            for L in (8, 16, 11, 8, 16, 5)]
    outs = sched.run()
    return [outs[r].tokens for r in rids]


def test_scheduler_tokens_bit_identical_with_and_without_telemetry(lm_system):
    """Acceptance: instrumentation only reads — greedy tokens from the
    disabled default, a disabled instance, and a fully enabled instance
    are all bitwise equal."""
    cfg, params = lm_system
    base = _sched_tokens(cfg, params)                       # module default
    off = _sched_tokens(cfg, params, Telemetry(enabled=False))
    on = Telemetry(enabled=True)
    instrumented = _sched_tokens(cfg, params, on)
    for a, b, c in zip(base, off, instrumented):
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, c)
    # and the enabled run actually observed the stack
    assert any(s.name == "round" for s in on.trace.spans)
    assert on.compile_count("sched") >= 3


# ---------------------------------------- bit-identity: gateway -------


@pytest.fixture(scope="module")
def gw_system():
    from repro.configs.agilenn_cifar import AgileNNConfig
    from repro.configs.base import AgileSpec
    from repro.core.agile import init_agile_params
    cfg = AgileNNConfig(image_size=16, remote_width=16, remote_blocks=2,
                        reference_width=16, reference_blocks=2,
                        agile=AgileSpec(enabled=True, extractor_channels=24,
                                        k=5, rho=0.8, lam=0.3, ig_steps=2))
    return cfg, init_agile_params(cfg, jax.random.PRNGKey(9))


def _gw_run(cfg, params, *, telemetry=None, faults=None):
    from repro.serve.gateway import (
        Fleet, GatewayConfig, OffloadGateway, mixed_fleet)
    specs = mixed_fleet(6, n_requests=2, slo_ms=8.0, deadline_ms=500.0)
    fleet = Fleet(cfg, params, specs, seed=5)
    return OffloadGateway(cfg, params, fleet, GatewayConfig(batch_width=4),
                          faults=faults, telemetry=telemetry).run()


def _trace_key(report):
    return [(t.client, t.req, t.t_born, t.t_sent, t.t_arrive, t.t_serve,
             t.t_done, t.e2e_s, t.energy_j, t.attempts, t.status)
            for t in report.traces]


def test_gateway_fault_run_bit_identical_with_telemetry(gw_system):
    """Acceptance: a seeded fault run's event-loop timeline, energy and
    statuses are bit-identical whether telemetry observes it or not."""
    from repro.serve.faults import BurstLoss, FaultInjector
    cfg, params = gw_system
    sched = (BurstLoss(0.0, 2.0, p_good_bad=0.3),)
    plain = _gw_run(cfg, params,
                    faults=FaultInjector(sched, seed=11))
    tel = Telemetry(enabled=True)
    seen = _gw_run(cfg, params, telemetry=tel,
                   faults=FaultInjector(sched, seed=11))
    assert _trace_key(plain) == _trace_key(seen)
    assert all(np.array_equal(a.logits, b.logits)
               for a, b in zip(plain.traces, seen.traces))
    assert tel.counter("gateway.requests", status="served").n > 0


def _union_coverage(spans, parent):
    """Fraction of ``parent``'s interval covered by the union of the
    other spans (clipped)."""
    ivs = sorted((max(s.t0, parent.t0), min(s.t1, parent.t1))
                 for s in spans if s is not parent)
    covered, end = 0.0, parent.t0
    for a, b in ivs:
        if b <= end:
            continue
        covered += b - max(a, end)
        end = b
    return covered / parent.dur if parent.dur > 0 else 1.0


def test_gateway_request_spans_cover_e2e_latency(gw_system):
    """Acceptance: per-request hop spans (device compute, radio
    attempts/backoff, uplink, queue wait, remote batch, response)
    account for >= 95% of every request's end-to-end latency."""
    cfg, params = gw_system
    tel = Telemetry(enabled=True)
    report = _gw_run(cfg, params, telemetry=tel)
    tracks = {s.track for s in tel.trace.spans
              if any(p.name == "request" for p in tel.trace.by_track(s.track))}
    assert len(tracks) == len(report.traces)
    for track in tracks:
        spans = tel.trace.by_track(track)
        parent = next(s for s in spans if s.name == "request")
        assert _union_coverage(spans, parent) >= 0.95, \
            f"{track}: uninstrumented gap in the request timeline"
