"""Flash attention (scan-based) vs the O(T*S) oracle, + decode paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.attention import (
    attention_apply,
    attention_decode_apply,
    attention_init,
    decode_attention,
    flash_attention,
    reference_attention,
)

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,T,Hq,Hkv,D,causal,window", [
    (2, 17, 4, 2, 8, True, 0),
    (1, 33, 6, 3, 16, True, 5),
    (2, 16, 4, 4, 8, False, 0),
    (1, 64, 8, 2, 32, True, 16),
    (1, 40, 2, 1, 4, True, 0),
])
def test_flash_matches_reference(B, T, Hq, Hkv, D, causal, window):
    kq, kk, kv = jax.random.split(KEY, 3)
    q = jax.random.normal(kq, (B, T, Hq, D))
    k = jax.random.normal(kk, (B, T, Hkv, D))
    v = jax.random.normal(kv, (B, T, Hkv, D))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_block=8, kv_block=8)
    ref = reference_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    kq, kk, kv = jax.random.split(KEY, 3)
    q = jax.random.normal(kq, (1, 32, 4, 16)).astype(dtype)
    k = jax.random.normal(kk, (1, 32, 2, 16)).astype(dtype)
    v = jax.random.normal(kv, (1, 32, 2, 16)).astype(dtype)
    out = flash_attention(q, k, v, q_block=16, kv_block=16)
    assert out.dtype == dtype
    ref = reference_attention(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), atol=tol, rtol=tol)


def test_decode_attention_prefix():
    kq, kk, kv = jax.random.split(KEY, 3)
    B, S, Hq, Hkv, D = 2, 32, 4, 2, 8
    q = jax.random.normal(kq, (B, 1, Hq, D))
    kc = jax.random.normal(kk, (B, S, Hkv, D))
    vc = jax.random.normal(kv, (B, S, Hkv, D))
    out = decode_attention(q, kc, vc, attend_len=10)
    ref = reference_attention(q, kc[:, :10], vc[:, :10], causal=False)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_decode_ring_buffer_roundtrip():
    """Decoding step-by-step with a ring buffer of size W matches windowed
    full attention."""
    cfgk = dict(n_heads=4, n_kv_heads=2, head_dim=8)
    d_model = 32
    W = 8
    params = attention_init(KEY, d_model, 4, 2, 8)
    T = 20
    x = 0.3 * jax.random.normal(KEY, (1, T, d_model))
    full = attention_apply(params, x, causal=True, window=W,
                           rope_theta=10000.0, **cfgk)
    k_cache = jnp.zeros((1, W, 2, 8))
    v_cache = jnp.zeros((1, W, 2, 8))
    outs = []
    for t in range(T):
        o, k_cache, v_cache = attention_decode_apply(
            params, x[:, t:t + 1], k_cache, v_cache, t,
            rope_theta=10000.0, **cfgk)
        outs.append(o)
    stepped = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(stepped, full, atol=2e-4, rtol=2e-4)


def test_chunked_prefill_q_offset():
    """flash_attention with q_offset continues a causal pattern."""
    kq, kk, kv = jax.random.split(KEY, 3)
    B, T, H, D = 1, 24, 2, 8
    q = jax.random.normal(kq, (B, T, H, D))
    k = jax.random.normal(kk, (B, T, H, D))
    v = jax.random.normal(kv, (B, T, H, D))
    full = reference_attention(q, k, v, causal=True)
    # second half of queries attending the whole K with offset
    half = flash_attention(q[:, 12:], k, v, causal=True, q_offset=12,
                           q_block=4, kv_block=8)
    np.testing.assert_allclose(half, full[:, 12:], atol=2e-5, rtol=2e-5)
