"""Overload-robust streaming frontend invariants.

The contract under test, from strongest to weakest traffic light:

  * with every overload feature disabled the frontend is a bit-identical
    pass-through over the continuous scheduler (greedy tokens unchanged,
    streaming is read-only);
  * under a 10x client stampede the admission queue stays bounded, the
    rejections are typed and deterministic, interactive traffic is never
    starved (p99 TTFT within the SLO) while best-effort is rejected, and
    every request resolves to exactly one ladder rung — nothing hangs;
  * the circuit breaker opens at the high watermark and only closes
    below the low one (hysteresis), deadline eviction composes with
    rejection, and the launcher refuses inapplicable flag combinations
    at parse time.
"""
import asyncio
import math

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import backbone as bb
from repro.serve.engine import Request
from repro.serve.faults import ArrivalBurst, FaultInjector, parse_faults
from repro.serve.frontend import (
    Delta,
    Finish,
    FirstToken,
    FrontendConfig,
    Overloaded,
    Priority,
    SimClient,
    StreamingFrontend,
    VirtualClock,
    drive_closed_loop,
)
from repro.serve.scheduler import ContinuousScheduler, SchedulerConfig

KEY = jax.random.PRNGKey(0)
SCHED = dict(buckets=(8, 16), max_slots=2, prefill_group=1, chunk=2)


@pytest.fixture(scope="module")
def system():
    cfg = get_config("qwen2-0.5b").reduced()
    params = bb.init_params(cfg, KEY)
    return cfg, params


def _requests(cfg, n, seed=0, max_new=5):
    rng = np.random.RandomState(seed)
    return [Request(tokens=rng.randint(0, cfg.vocab,
                                       int(rng.choice((4, 8, 12)))),
                    max_new_tokens=max_new)
            for _ in range(n)]


def _frontend(cfg, params, *, frontend=None, clock=None, **sched_kw):
    kw = dict(SCHED)
    kw.update(sched_kw)
    return StreamingFrontend(cfg, params, frontend=frontend,
                             sched=SchedulerConfig(**kw), max_len=32,
                             seed=0, clock=clock)


def _stampede_fleet(cfg, n_per_class=4, n_reqs=3, think_s=0.0):
    """A closed-loop fleet whose offered load is ~10x the 2-slot pool."""
    clients = []
    for c in range(3 * n_per_class):
        clients.append(SimClient(
            requests=tuple(_requests(cfg, n_reqs, seed=c)),
            priority=Priority(c % 3), start_s=0.05 * c, think_s=think_s))
    return clients


# ------------------------------------------------------- bit-identity --


def test_passthrough_tokens_bit_identical(system):
    """Defaults (no queue bound, no SLO, one class) = pass-through: the
    scheduler sees submission order and greedy tokens are unchanged."""
    cfg, params = system
    reqs = _requests(cfg, 8)
    ref = ContinuousScheduler(cfg, params, sched=SchedulerConfig(**SCHED),
                              max_len=32, seed=0)
    rids = [ref.submit(r) for r in reqs]
    out = ref.run()
    want = {i: np.asarray(out[rid].tokens) for i, rid in enumerate(rids)}
    fe = _frontend(cfg, params, clock=VirtualClock())
    fids = [fe.submit(r) for r in reqs]
    got = fe.run()
    for i, fid in enumerate(fids):
        status, toks = got[fid]
        assert status == "served"
        np.testing.assert_array_equal(toks, want[i])


def test_stream_events_reassemble_exactly(system):
    """Per-request event streams are FirstToken, Delta*, Finish, in
    token order, and concatenating the token events reproduces the
    completion bit-for-bit."""
    cfg, params = system
    reqs = _requests(cfg, 6)
    fe = _frontend(cfg, params, clock=VirtualClock())
    fids = [fe.submit(r) for r in reqs]
    results = fe.run()
    per = {fid: [ev for ev in fe.events if ev.rid == fid] for fid in fids}
    for fid in fids:
        evs = per[fid]
        assert isinstance(evs[0], FirstToken)
        assert isinstance(evs[-1], Finish)
        assert all(isinstance(e, Delta) for e in evs[1:-1])
        toks = [e.token for e in evs[:-1]]
        np.testing.assert_array_equal(toks, results[fid][1])
        # timestamps are monotone along the stream
        ts = [e.t for e in evs]
        assert ts == sorted(ts)


def test_streaming_is_incremental_not_bulk(system):
    """A long decode publishes tokens across multiple rounds — the
    stream is not one bulk dump at completion."""
    cfg, params = system
    fe = _frontend(cfg, params, clock=VirtualClock())
    fid = fe.submit(Request(tokens=list(range(1, 9)), max_new_tokens=12))
    rounds = []
    while fe.has_work():
        evs = fe.step()
        rounds.append(sum(isinstance(e, (FirstToken, Delta))
                          for e in evs if e.rid == fid))
    assert sum(rounds) == 12
    assert sum(1 for n in rounds if n) > 1, \
        "all tokens arrived in a single round — streaming is bulk"


# ----------------------------------------------------------- overload --


def test_stampede_bounds_queue_and_rejects_deterministically(system):
    """The acceptance scenario: scripted 10x ArrivalBurst into a bounded
    frontend.  Queue depth never exceeds the bound, interactive p99 TTFT
    holds the SLO while best-effort is rejected, every request resolves
    on the ladder, and a rerun is event-for-event identical."""
    cfg, params = system

    def run():
        clock = VirtualClock()
        fe = _frontend(
            cfg, params, clock=clock,
            frontend=FrontendConfig(max_queue=4, slo_ms=250.0))
        depths = []
        orig_step = fe.step

        def step():
            evs = orig_step()
            depths.append(fe.queue_depth())
            return evs

        fe.step = step
        rep = drive_closed_loop(
            fe, _stampede_fleet(cfg), clock=clock, round_s=0.01,
            faults=FaultInjector((ArrivalBurst(factor=10.0),), seed=7))
        return rep, depths

    rep, depths = run()
    assert max(depths) <= 4, f"queue depth {max(depths)} broke the bound"
    assert all(r.status in ("served", "shed", "rejected")
               for r in rep.records), "a request left the ladder"
    ttft = rep.ttft_ms(Priority.INTERACTIVE)
    assert len(ttft) and float(np.percentile(ttft, 99)) <= 250.0, \
        "interactive starved: p99 TTFT above the SLO under stampede"
    be = rep.of(Priority.BEST_EFFORT)
    assert any(r.status == "rejected" for r in be), \
        "a 10x stampede must reject best-effort at admission"
    for r in rep.records:
        if r.status == "rejected":
            assert r.retry_after_s > 0.0
    rep2, depths2 = run()
    assert depths == depths2
    assert [(r.status, r.t_submit, r.t_done) for r in rep.records] \
        == [(r.status, r.t_submit, r.t_done) for r in rep2.records], \
        "rerun diverged — overload behaviour is not deterministic"


def test_overloaded_is_typed_with_retry_hint(system):
    cfg, params = system
    fe = _frontend(cfg, params, clock=VirtualClock(),
                   frontend=FrontendConfig(max_queue=2))
    for r in _requests(cfg, 2):
        fe.submit(r)
    with pytest.raises(Overloaded) as ei:
        fe.submit(_requests(cfg, 1)[0])
    assert ei.value.reason == "queue full"
    assert ei.value.queue_depth == 2
    assert ei.value.retry_after_s > 0.0
    fe.run()    # the two admitted requests still drain


def test_breaker_hysteresis(system):
    """The breaker opens at the high watermark, sheds BEST_EFFORT, and
    stays open until depth falls below the LOW watermark — no flapping
    in the band between the two."""
    cfg, params = system
    fe = _frontend(cfg, params, clock=VirtualClock(),
                   frontend=FrontendConfig(max_queue=8, breaker_high=0.75,
                                           breaker_low=0.25))
    req = _requests(cfg, 1)[0]
    for depth in (4, 5):                      # below high: closed
        fe.queue_depth = lambda d=depth: d
        fe._update_breaker()
        assert not fe.breaker_open
    fe.queue_depth = lambda: 6                # at high (0.75 * 8): opens
    with pytest.raises(Overloaded) as ei:
        fe.submit(req, Priority.BEST_EFFORT)
    assert ei.value.reason == "breaker"
    assert fe.breaker_open
    fe.queue_depth = lambda: 4                # inside the band: stays open
    with pytest.raises(Overloaded):
        fe.submit(req, Priority.BEST_EFFORT)
    fe.queue_depth = lambda: 2                # at low (0.25 * 8): closes
    fe._update_breaker()
    assert not fe.breaker_open
    fid = fe.submit(req, Priority.BEST_EFFORT)
    del fe.queue_depth                        # restore the real method
    assert fe.run()[fid][0] == "served"


def test_feed_order_is_priority_then_edf(system):
    """With metered feeding, release order is best class first and
    earliest deadline first within a class, regardless of submission
    order (FIFO only on deadline ties)."""
    cfg, params = system
    fe = _frontend(cfg, params, clock=VirtualClock(),
                   frontend=FrontendConfig(max_queue=16, feed_depth=1))
    order = []
    orig = fe.sched.submit

    def spy(req, **kw):
        order.append(req.max_new_tokens)
        return orig(req, **kw)

    fe.sched.submit = spy
    rng = np.random.RandomState(0)

    def req(tag, dl):
        return Request(tokens=rng.randint(0, cfg.vocab, 4),
                       max_new_tokens=tag, deadline_s=dl)

    fe.submit(req(3, None), Priority.BEST_EFFORT)
    fe.submit(req(4, 50.0), Priority.BATCH)
    fe.submit(req(5, 90.0), Priority.INTERACTIVE)
    fe.submit(req(6, 40.0), Priority.INTERACTIVE)
    fe.submit(req(7, None), Priority.INTERACTIVE)
    results = fe.run()
    # interactive EDF (40 < 90 < no-deadline), then batch, then best-effort
    assert order == [6, 5, 7, 4, 3]
    assert all(st == "served" for st, _ in results.values())


def test_deadline_eviction_composes_with_rejection(system):
    """A waiting request whose deadline lapses is shed (never prefilled),
    a fourth arrival past the bound is rejected, and the survivors are
    served — three ladder rungs out of one overload episode."""
    cfg, params = system
    clock = VirtualClock()
    fe = _frontend(cfg, params, clock=clock,
                   frontend=FrontendConfig(max_queue=3))
    rng = np.random.RandomState(0)
    r_ok = fe.submit(Request(tokens=rng.randint(0, cfg.vocab, 4),
                             max_new_tokens=4))
    r_dead = fe.submit(Request(tokens=rng.randint(0, cfg.vocab, 4),
                               max_new_tokens=4, deadline_s=0.05))
    r_slow = fe.submit(Request(tokens=rng.randint(0, cfg.vocab, 4),
                               max_new_tokens=4, deadline_s=60.0))
    with pytest.raises(Overloaded):
        fe.submit(Request(tokens=rng.randint(0, cfg.vocab, 4),
                          max_new_tokens=4))
    clock.now += 0.1                      # r_dead's deadline lapses
    results = fe.run()
    assert results[r_ok][0] == "served"
    assert results[r_dead][0] == "shed"
    assert len(results[r_dead][1]) < 4    # shed partial, never completed
    assert results[r_slow][0] == "served"


# ------------------------------------------------------- ArrivalBurst --


def test_arrival_burst_closed_form():
    inj = FaultInjector((ArrivalBurst(t0=1.0, t1=3.0, factor=4.0),))
    assert inj.arrival_time(0, 2.0) == pytest.approx(1.25)
    assert inj.arrival_time(0, 1.0) == pytest.approx(1.0)
    assert inj.arrival_time(0, 0.5) == 0.5      # before the window
    assert inj.arrival_time(0, 3.0) == 3.0      # at/after the window
    scoped = FaultInjector((ArrivalBurst(factor=10.0, clients=(1,)),))
    assert scoped.arrival_time(0, 2.0) == 2.0   # other clients untouched
    assert scoped.arrival_time(1, 2.0) == pytest.approx(0.2)
    with pytest.raises(ValueError):
        ArrivalBurst(factor=0.5)


def test_parse_faults_stampede_roundtrip():
    (ev,) = parse_faults("stampede:1:3:4")
    assert ev == ArrivalBurst(t0=1.0, t1=3.0, factor=4.0)
    (ev,) = parse_faults("stampede")
    assert ev == ArrivalBurst()
    assert math.isinf(ev.t1) and ev.factor == 10.0


def test_gateway_stampede_resolves_on_ladder():
    """The gateway under ArrivalBurst + bounded admission: every request
    resolves to exactly one ladder rung and overload is refused at the
    door (rejected), not buffered."""
    from repro.configs.agilenn_cifar import gateway_demo_config
    from repro.core.agile import init_agile_params
    from repro.serve.gateway import (
        Fleet, GatewayConfig, OffloadGateway, mixed_fleet)

    cfg = gateway_demo_config()
    params = init_agile_params(cfg, jax.random.PRNGKey(0))
    specs = mixed_fleet(8, n_requests=4, deadline_ms=150.0)
    fleet = Fleet(cfg, params, specs, seed=0)
    inj = FaultInjector((ArrivalBurst(factor=10.0),), seed=7)
    rep = OffloadGateway(cfg, params, fleet,
                         GatewayConfig(batch_width=4, max_queue=2),
                         faults=inj).run()
    assert len(rep.traces) == 8 * 4
    ladder = {"served", "degraded", "shed", "rejected", "fallback"}
    assert {tr.status for tr in rep.traces} <= ladder
    assert rep.rejected_rate > 0.0


# ------------------------------------------------------------- async --


def test_async_stream_matches_run(system):
    """The asyncio iterator yields the same typed events the sync path
    records, terminated by Finish, with serve_forever driving rounds."""
    cfg, params = system
    reqs = _requests(cfg, 2)

    async def go():
        fe = _frontend(cfg, params, clock=VirtualClock())
        server = asyncio.ensure_future(fe.serve_forever())
        evs = [await _collect(fe.stream(r)) for r in reqs]
        fe.close()
        await server
        return fe, evs

    fe, evs = asyncio.run(go())
    ref = _frontend(cfg, params, clock=VirtualClock())
    fids = [ref.submit(r) for r in reqs]
    want = ref.run()
    for fid, stream in zip(fids, evs):
        assert isinstance(stream[0], FirstToken)
        assert isinstance(stream[-1], Finish)
        assert stream[-1].status == "served"
        np.testing.assert_array_equal(
            [e.token for e in stream[:-1]], want[fid][1])


def test_async_wait_turns_rejection_into_backpressure(system):
    """stream(..., wait=True) retries after the hint instead of failing:
    the client slows down, the request eventually serves."""
    cfg, params = system

    async def go():
        fe = _frontend(cfg, params,
                       frontend=FrontendConfig(max_queue=1))
        r1, r2 = _requests(cfg, 2)
        server = asyncio.ensure_future(fe.serve_forever())
        first = asyncio.ensure_future(
            _collect(fe.stream(r1, Priority.INTERACTIVE)))
        await asyncio.sleep(0)            # r1 admitted, queue now full
        second = await _collect(fe.stream(r2, Priority.INTERACTIVE,
                                          wait=True))
        fe.close()
        await server
        return await first, second

    evs1, evs2 = asyncio.run(go())
    assert evs1[-1].status == "served"
    assert evs2[-1].status == "served"


async def _collect(aiter):
    return [ev async for ev in aiter]


# --------------------------------------------------- launcher guards --


@pytest.mark.parametrize("argv", [
    ["--arch", "qwen2-0.5b", "--prefix-cache"],
    ["--arch", "qwen2-0.5b", "--serialized"],
    ["--slo-ms", "40"],
    ["--arch", "qwen2-0.5b", "--local", "--slo-ms", "40"],
    ["--arch", "qwen2-0.5b", "--queue", "4", "--max-queue", "2"],
    ["--arch", "qwen2-0.5b", "--queue", "4", "--priority", "batch"],
    ["--arch", "qwen2-0.5b", "--queue", "4", "--slo-ms", "40"],
    ["--arch", "qwen2-0.5b", "--preempt"],
    ["--arch", "qwen2-0.5b", "--queue", "4", "--preempt"],
    ["--arch", "qwen2-0.5b", "--journal", "j.jsonl"],
    ["--arch", "qwen2-0.5b", "--queue", "4", "--journal", "j.jsonl"],
    ["--gateway", "4", "--preempt"],
    ["--faults", "stampede"],
    ["--arch", "qwen2-0.5b", "--deadline-ms", "100"],
    ["--gateway", "4", "--queue", "4"],
    ["--gateway", "4", "--mesh", "2"],
])
def test_launcher_rejects_inapplicable_flags(argv):
    """Scoped flags outside their mode are parse-time errors (argparse
    exits 2), not silent no-ops."""
    from repro.launch.serve import main
    with pytest.raises(SystemExit) as ei:
        main(argv)
    assert ei.value.code == 2
