"""Preemptible serving + crash-consistent journal invariants.

The contract under test, strongest first:

  * suspend/resume is *exact*: a request evicted mid-decode and
    re-admitted through the chunked-prefill path produces greedy tokens
    bit-identical to an uninterrupted run — including when suspended
    twice;
  * priority preemption frees a slot for an aged INTERACTIVE waiter by
    suspending the worst pooled row; the victim re-enters its class
    queue (never dropped) and everything still completes bit-identically
    while interactive TTFT improves;
  * an attached journal is a bit-identical pass-through (events and
    tokens unchanged) and its records reassemble every token stream;
  * crash + replay is exact and exactly-once: for a crash injected at
    *every* scheduling round, recovery reconstructs the journal into a
    fresh frontend and the union of pre-crash and replayed finishes
    covers each request once with bit-identical tokens — including when
    the crash tears the journal's final line;
  * the launcher rejects --preempt / --journal outside --stream at parse
    time, and the whole preempt+crash+recover path honours the
    telemetry zero-overhead contract (disabled observation never changes
    tokens; enabled observation sees the new counters and spans).
"""
import math

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import backbone as bb
from repro.serve.engine import Request
from repro.serve.faults import EngineCrash, EngineCrashError, FaultInjector
from repro.serve.frontend import (
    Finish,
    FirstToken,
    FrontendConfig,
    Priority,
    StreamingFrontend,
    Suspended,
    VirtualClock,
)
from repro.serve.recovery import (
    RequestJournal,
    recover,
    recovery_plan,
)
from repro.serve.scheduler import ContinuousScheduler, SchedulerConfig
from repro.serve.telemetry import Telemetry

KEY = jax.random.PRNGKey(0)
SCHED = dict(buckets=(8, 16), max_slots=2, prefill_group=1, chunk=2)


@pytest.fixture(scope="module")
def system():
    cfg = get_config("qwen2-0.5b").reduced()
    params = bb.init_params(cfg, KEY)
    return cfg, params


def _requests(cfg, n, seed=0, max_new=5):
    rng = np.random.RandomState(seed)
    return [Request(tokens=rng.randint(0, cfg.vocab,
                                       int(rng.choice((4, 8, 12)))),
                    max_new_tokens=max_new)
            for _ in range(n)]


def _sched(cfg, params, *, faults=None, **kw):
    skw = dict(SCHED)
    skw.update(kw)
    return ContinuousScheduler(cfg, params, sched=SchedulerConfig(**skw),
                               max_len=48, seed=0, faults=faults)


def _frontend(cfg, params, *, frontend=None, clock=None, faults=None,
              telemetry=None, journal=None, **sched_kw):
    kw = dict(SCHED)
    kw.update(sched_kw)
    return StreamingFrontend(cfg, params, frontend=frontend,
                             sched=SchedulerConfig(**kw), max_len=48,
                             seed=0, clock=clock, faults=faults,
                             telemetry=telemetry, journal=journal)


# --------------------------------------------------- suspend / resume --


def test_suspend_resume_tokens_bit_identical(system):
    """A request evicted mid-decode and re-admitted (prompt + generated
    tokens through the ordinary prefill path) finishes with greedy
    tokens bit-identical to never having been suspended."""
    cfg, params = system
    reqs = _requests(cfg, 3, max_new=8)
    ref = _sched(cfg, params)
    rids = [ref.submit(r) for r in reqs]
    refout = ref.run()
    want = {i: np.asarray(refout[rid].tokens)
            for i, rid in enumerate(rids)}

    sched = _sched(cfg, params)
    rids = [sched.submit(r) for r in reqs]
    done = set()
    for _ in range(3):                     # decode a few partial chunks
        done.update(sched.step())
    sus = sched.suspend(rids[0])
    assert sus is not None and rids[0] not in sched._slot_rid
    n_pre = len(sus.generated)
    assert 0 < n_pre < 8                   # genuinely mid-decode
    for _ in range(2):                     # victim's slot serves others
        done.update(sched.step())
    new_rid = sched.submit_suspended(sus)
    while sched.has_work():
        done.update(sched.step())
    outs = {r: sched.pop_completion(r) for r in done}
    np.testing.assert_array_equal(outs[new_rid].tokens, want[0])
    assert outs[new_rid].steps == len(want[0])
    for i in (1, 2):
        np.testing.assert_array_equal(outs[rids[i]].tokens, want[i])


def test_double_suspend_still_bit_identical(system):
    """Suspension chains: a resumed request preempted a second time
    still finishes bit-identically (the resume prefix accumulates)."""
    cfg, params = system
    req = _requests(cfg, 1, max_new=12)[0]
    ref = _sched(cfg, params)
    rid = ref.submit(req)
    want = np.asarray(ref.run()[rid].tokens)

    sched = _sched(cfg, params)
    rid = sched.submit(req)
    for _ in range(2):
        sched.step()
    sus = sched.suspend(rid)
    assert sus is not None
    n_first = len(sus.generated)
    assert 0 < n_first < 12
    rid = sched.submit_suspended(sus)
    sched.step()
    sus = sched.suspend(rid)
    assert sus is not None
    assert len(sus.generated) > n_first    # the prefix accumulated
    rid = sched.submit_suspended(sus)
    outs = sched.run()
    np.testing.assert_array_equal(np.asarray(outs[rid].tokens), want)


def _drive(fe, clock, round_s=0.01):
    while fe.has_work():
        clock.now += round_s
        fe.step()
    out, fe._results = fe._results, {}
    return out


def test_preemption_suspends_worst_row_for_interactive(system):
    """With SchedulerConfig.preempt, an INTERACTIVE arrival facing a
    full pool suspends the lowest-priority pooled row: the victim lands
    back in its class queue as a Suspended, the interactive request's
    first token arrives earlier than without preemption, and every
    request (victim included) still serves bit-identical tokens."""
    cfg, params = system
    hogs = _requests(cfg, 2, seed=1, max_new=10)
    inter = _requests(cfg, 1, seed=2, max_new=4)[0]
    ref = _sched(cfg, params)
    rids = [ref.submit(r) for r in hogs + [inter]]
    refout = ref.run()
    want = [np.asarray(refout[r].tokens) for r in rids]

    def run(preempt):
        clock = VirtualClock()
        fe = _frontend(cfg, params, clock=clock, preempt=preempt,
                       frontend=FrontendConfig(max_queue=8, feed_depth=1,
                                               preempt_wait_ms=0.0))
        fids = [fe.submit(h, Priority.BEST_EFFORT) for h in hogs]
        # let both hogs reach the pool before the interactive arrival
        while fe.sched._free_slots() and fe.has_work():
            clock.now += 0.01
            fe.step()
        saw_suspend = False
        fids.append(fe.submit(inter, Priority.INTERACTIVE))
        while fe.has_work():
            clock.now += 0.01
            fe.step()
            saw_suspend |= any(isinstance(r, Suspended)
                               for r in fe._reqs.values())
        out, fe._results = fe._results, {}
        ttft = {ev.rid: ev.t for ev in fe.events
                if isinstance(ev, FirstToken)}
        return fids, out, ttft, saw_suspend

    fids_p, out_p, ttft_p, suspended = run(True)
    fids_n, out_n, ttft_n, _ = run(False)
    assert suspended, "preemption never suspended a pooled row"
    for fids, out in ((fids_p, out_p), (fids_n, out_n)):
        for i, fid in enumerate(fids):
            status, toks = out[fid]
            assert status == "served"
            np.testing.assert_array_equal(toks, want[i])
    # the preempted run starts the interactive stream strictly earlier
    assert ttft_p[fids_p[2]] < ttft_n[fids_n[2]]


# -------------------------------------------------- journal: attached --


def _ev_key(ev):
    toks = (tuple(int(x) for x in ev.tokens)
            if isinstance(ev, Finish) else None)
    status = ev.status if isinstance(ev, Finish) else None
    tok = getattr(ev, "token", None)
    return (type(ev).__name__, ev.rid, tok, status, toks, ev.t)


def test_journal_is_bit_identical_passthrough(system):
    """Attaching a journal changes nothing observable: events (types,
    rids, tokens, timestamps) and results are bit-identical to the
    journal-less run, and the journal's chunk records reassemble every
    served stream exactly."""
    cfg, params = system
    reqs = _requests(cfg, 6)
    plain = _frontend(cfg, params, clock=VirtualClock())
    fids = [plain.submit(r) for r in reqs]
    want = plain.run()

    j = RequestJournal()
    fe = _frontend(cfg, params, clock=VirtualClock(), journal=j)
    fids2 = [fe.submit(r) for r in reqs]
    got = fe.run()
    assert fids2 == fids
    assert [_ev_key(e) for e in fe.events] == \
        [_ev_key(e) for e in plain.events]
    for fid in fids:
        assert want[fid][0] == got[fid][0]
        np.testing.assert_array_equal(want[fid][1], got[fid][1])
    # well-formed: per-rid lifecycle order and exact token reassembly
    by_rid = {}
    for rec in j.events:
        by_rid.setdefault(rec["rid"], []).append(rec)
    assert set(by_rid) == set(fids)
    for fid in fids:
        kinds = [r["ev"] for r in by_rid[fid]]
        assert kinds[0] == "submit" and kinds[1] == "admit" \
            and kinds[-1] == "finish"
        assert all(k == "chunk" for k in kinds[2:-1])
        toks = [t for r in by_rid[fid] if r["ev"] == "chunk"
                for t in r["toks"]]
        np.testing.assert_array_equal(np.asarray(toks, np.int32),
                                      got[fid][1])
        assert by_rid[fid][-1]["n"] == len(toks)
        ts = [r["t"] for r in by_rid[fid]]
        assert ts == sorted(ts)


# ---------------------------------------------------- crash + replay --


def _run_reference(cfg, params, reqs, prios):
    clock = VirtualClock()
    fe = _frontend(cfg, params, clock=clock)
    fids = [fe.submit(r, p) for r, p in zip(reqs, prios)]
    out = _drive(fe, clock)
    return fids, out, fe.sched._round


def test_crash_replay_bit_identical_at_every_round(system):
    """Sweep EngineCrash across every scheduling round of a pinned
    workload: recovery replays the journal into a fresh frontend and the
    merged results cover each admitted request exactly once with tokens
    bit-identical to the crash-free run (exactly-once Finish: the
    pre-crash and recovered finish sets never overlap)."""
    cfg, params = system
    reqs = _requests(cfg, 4, max_new=4)
    prios = [Priority.INTERACTIVE, Priority.BATCH,
             Priority.BEST_EFFORT, Priority.INTERACTIVE]
    fids, want, n_rounds = _run_reference(cfg, params, reqs, prios)
    assert n_rounds >= 4                   # the sweep is non-trivial
    for r in range(n_rounds):
        j = RequestJournal()
        clock = VirtualClock()
        fe = _frontend(cfg, params, clock=clock, journal=j,
                       faults=FaultInjector((EngineCrash(r),)))
        got_fids = [fe.submit(q, p) for q, p in zip(reqs, prios)]
        assert got_fids == fids
        with pytest.raises(EngineCrashError):
            _drive(fe, clock)
        pre = {ev.rid for ev in fe.events if isinstance(ev, Finish)}

        clock2 = VirtualClock(clock.now)
        fe2 = _frontend(cfg, params, clock=clock2)
        merged = recover(fe2, j, drive=lambda: _drive(fe2, clock2))
        post = {ev.rid for ev in fe2.events if isinstance(ev, Finish)}
        assert not pre & post, f"round {r}: duplicate Finish delivery"
        assert set(merged) == set(fids), f"round {r}: lost requests"
        for fid in fids:
            status, toks = merged[fid]
            assert status == "served"
            np.testing.assert_array_equal(
                toks, want[fid][1],
                err_msg=f"crash at round {r}: rid {fid} diverged")


def test_torn_final_journal_line_is_dropped_and_recovered(tmp_path, system):
    """A torn final line (the partial write a real crash leaves) fails
    its crc and is dropped; the request that lost only its finish record
    resolves from its journaled chunks — logically complete — with
    bit-identical tokens and nothing replayed."""
    cfg, params = system
    path = str(tmp_path / "journal.jsonl")
    reqs = _requests(cfg, 3, max_new=4)
    clock = VirtualClock()
    with RequestJournal(path) as j:
        fe = _frontend(cfg, params, clock=clock, journal=j)
        fids = [fe.submit(q) for q in reqs]
        want = _drive(fe, clock)
    whole = RequestJournal.read(path)
    assert [
        (r["ev"], r["rid"]) for r in whole
    ] == [(r["ev"], r["rid"]) for r in j.events]
    assert whole[-1]["ev"] == "finish"

    with open(path, "rb") as f:
        raw = f.read()
    with open(path, "wb") as f:
        f.write(raw[:-4])                  # tear the last record
    events = RequestJournal.read(path)
    assert len(events) == len(whole) - 1   # only the torn line is lost

    plan = recovery_plan(events)
    assert not plan.replay                 # finish was all the crash ate
    fe2 = _frontend(cfg, params, clock=VirtualClock())
    merged = recover(fe2, events)
    assert set(merged) == set(fids)
    for fid in fids:
        status, toks = merged[fid]
        assert status == "served"
        np.testing.assert_array_equal(toks, want[fid][1])


def test_recovery_replays_never_admitted_submissions(system):
    """A crash at round 0 leaves some requests journaled as submitted
    but never admitted to the pool; recovery replays them from their
    prompts alone."""
    cfg, params = system
    reqs = _requests(cfg, 4, max_new=4)
    fids, want, _ = _run_reference(cfg, params, reqs,
                                   [Priority.INTERACTIVE] * 4)
    j = RequestJournal()
    clock = VirtualClock()
    fe = _frontend(cfg, params, clock=clock, journal=j,
                   faults=FaultInjector((EngineCrash(0),)))
    for q in reqs:
        fe.submit(q)
    with pytest.raises(EngineCrashError):
        _drive(fe, clock)
    plan = recovery_plan(j.events)
    assert {it.rid for it in plan.replay} == set(fids)
    assert all(len(it.generated) == 0 for it in plan.replay)
    clock2 = VirtualClock()
    fe2 = _frontend(cfg, params, clock=clock2)
    merged = recover(fe2, j, drive=lambda: _drive(fe2, clock2))
    for fid in fids:
        np.testing.assert_array_equal(merged[fid][1], want[fid][1])


# ------------------------------------------------- telemetry contract --


def _chaos_run(cfg, params, telemetry=None):
    """Preempt + crash + recover under one telemetry posture; returns
    the merged results (rid -> (status, tokens))."""
    hogs = _requests(cfg, 2, seed=1, max_new=8)
    inter = _requests(cfg, 1, seed=2, max_new=4)[0]
    j = RequestJournal(telemetry=telemetry)
    clock = VirtualClock()
    fe = _frontend(cfg, params, clock=clock, journal=j,
                   telemetry=telemetry, preempt=True,
                   faults=FaultInjector((EngineCrash(6),)),
                   frontend=FrontendConfig(max_queue=8, feed_depth=1,
                                           preempt_wait_ms=0.0))
    for h in hogs:
        fe.submit(h, Priority.BEST_EFFORT)
    while fe.sched._free_slots() and fe.has_work():
        clock.now += 0.01
        fe.step()
    fe.submit(inter, Priority.INTERACTIVE)
    with pytest.raises(EngineCrashError):
        _drive(fe, clock)
    clock2 = VirtualClock(clock.now)
    fe2 = _frontend(cfg, params, clock=clock2, telemetry=telemetry)
    return recover(fe2, j, drive=lambda: _drive(fe2, clock2))


def test_chaos_path_honours_telemetry_zero_overhead_contract(system):
    """The whole preempt+journal+crash+recover path is observation-only:
    tokens are bit-identical across the module default, an explicitly
    disabled Telemetry, and a fully enabled one — and the enabled run
    records the new counters and the recovery span."""
    cfg, params = system
    base = _chaos_run(cfg, params)
    off = _chaos_run(cfg, params, Telemetry(enabled=False))
    on = Telemetry(enabled=True)
    seen = _chaos_run(cfg, params, on)
    assert set(base) == set(off) == set(seen)
    for rid in base:
        assert base[rid][0] == off[rid][0] == seen[rid][0]
        np.testing.assert_array_equal(base[rid][1], off[rid][1])
        np.testing.assert_array_equal(base[rid][1], seen[rid][1])
    assert on.counter("frontend.preempted",
                      victim=Priority.BEST_EFFORT.name).n >= 1
    assert on.counter("sched.resumed").n >= 1
    assert on.counter("journal.events", ev="submit").n >= 3
    assert on.counter("recovery.replayed").n >= 1
    assert any(s.name == "recovery.replay" for s in on.trace.spans)
