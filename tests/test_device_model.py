"""Closed-form unit tests for the embedded-device cost model (§6-§7).

The gateway's timestamps and energy accounting all derive from
`DeviceModel`; these tests pin every formula so a silent constant or
unit change cannot drift the simulated fleet."""
import dataclasses

import pytest

from repro.serve.device_model import DeviceModel, InferenceCost, mcu_memory_model

WIFI = DeviceModel()                                   # 6 Mbps ESP-WROOM
NARROW = DeviceModel(link_bps=270e3)                   # narrowband option


def test_compute_time_closed_form():
    d = DeviceModel(cpu_hz=216e6, macs_per_cycle=1.0)
    assert d.compute_time(216e6) == pytest.approx(1.0)
    assert d.compute_time(108e6) == pytest.approx(0.5)
    # a 2-MAC/cycle device halves the time exactly
    d2 = dataclasses.replace(d, macs_per_cycle=2.0)
    assert d2.compute_time(216e6) == pytest.approx(0.5)


def test_tx_time_closed_form():
    assert WIFI.tx_time(750_000) == pytest.approx(1.0)     # 6 Mbit at 6 Mbps
    assert NARROW.tx_time(1000) == pytest.approx(8000 / 270e3)
    # link ratio is exactly the bandwidth ratio
    assert NARROW.tx_time(1234) / WIFI.tx_time(1234) == pytest.approx(
        6e6 / 270e3)


def test_server_time_closed_form():
    d = DeviceModel(server_macs_per_s=5e12, server_overhead_s=1e-3)
    assert d.server_time(0) == pytest.approx(1e-3)
    assert d.server_time(5e9) == pytest.approx(1e-3 + 1e-3)


def test_energy_closed_form():
    d = DeviceModel(p_cpu_w=0.33, p_tx_w=0.56)
    macs, nbytes = 216e6, 750_000 * (WIFI.link_bps / 6e6)
    expect = 0.33 * d.compute_time(macs) + 0.56 * d.tx_time(nbytes)
    assert d.energy(macs, nbytes) == pytest.approx(expect)
    # energy is linear in both arguments
    assert d.energy(2 * macs, 0) == pytest.approx(2 * d.energy(macs, 0))
    assert d.energy(0, 2 * nbytes) == pytest.approx(2 * d.energy(0, nbytes))


def test_compute_vs_tx_crossover():
    """The payload size where radio time overtakes local compute is
    macs * link_bps / (8 * cpu_hz); the cost model must agree on both
    sides of it."""
    d = WIFI
    macs = 1e6
    crossover = macs * d.link_bps / (8.0 * d.cpu_hz)
    assert d.tx_time(0.5 * crossover) < d.compute_time(macs)
    assert d.tx_time(2.0 * crossover) > d.compute_time(macs)
    assert d.tx_time(crossover) == pytest.approx(d.compute_time(macs))
    # narrowband pulls the crossover proportionally lower
    n_cross = macs * NARROW.link_bps / (8.0 * NARROW.cpu_hz)
    assert n_cross / crossover == pytest.approx(270e3 / 6e6)
    assert NARROW.tx_time(2.0 * n_cross) > NARROW.compute_time(macs)


def test_narrowband_tx_dominates_energy():
    """On the narrowband link the radio, not the CPU, dominates energy
    for payloads past the crossover — the effect the rate controller
    exploits."""
    macs, nbytes = 1e6, 2000
    assert NARROW.p_tx_w * NARROW.tx_time(nbytes) > \
        NARROW.p_cpu_w * NARROW.compute_time(macs)
    # same payload on WiFi: compute dominates instead
    assert WIFI.p_tx_w * WIFI.tx_time(nbytes) < \
        WIFI.p_cpu_w * WIFI.compute_time(macs)


def test_inference_cost_end_to_end_sum():
    c = InferenceCost(local_compute_s=1e-3, tx_s=2e-3, server_s=3e-3,
                      payload_bytes=100, local_macs=1e5, remote_macs=1e7)
    assert c.end_to_end_s == pytest.approx(6e-3)
    d = c.as_dict
    assert d["end_to_end_ms"] == pytest.approx(6.0)
    assert d["local_compute_ms"] + d["tx_ms"] + d["server_ms"] == \
        pytest.approx(d["end_to_end_ms"])


def test_mcu_memory_model_int8_vs_float():
    int8 = mcu_memory_model(100_000, 50_000, int8=True)
    f32 = mcu_memory_model(100_000, 50_000, int8=False)
    assert int8["flash_bytes"] == 100_000 and f32["flash_bytes"] == 400_000
    assert int8["sram_bytes"] == 50_000 and f32["sram_bytes"] == 200_000
