"""The --compare regression gate: machine-normalized, workload-pinned."""
import json

from benchmarks.run import compare_rows


def _baseline(tmp_path):
    p = tmp_path / "baseline.json"
    p.write_text(json.dumps({"suites": ["kernels"], "rows": [
        {"name": "kernel.a.us", "value": 100.0, "derived": "x"},
        {"name": "kernel.b.us", "value": 100.0, "derived": "x"},
        {"name": "serve.c_tokens_per_s", "value": 50.0, "derived": "w"},
    ]}))
    return str(p)


def test_uniform_slowdown_is_machine_speed_not_regression(tmp_path):
    rows = [{"name": "kernel.a.us", "value": 200.0, "derived": "x"},
            {"name": "kernel.b.us", "value": 200.0, "derived": "x"},
            {"name": "serve.c_tokens_per_s", "value": 25.0, "derived": "w"}]
    assert compare_rows(rows, _baseline(tmp_path)) == []


def test_single_row_regression_detected(tmp_path):
    rows = [{"name": "kernel.a.us", "value": 100.0, "derived": "x"},
            {"name": "kernel.b.us", "value": 300.0, "derived": "x"},
            {"name": "serve.c_tokens_per_s", "value": 50.0, "derived": "w"}]
    regs = compare_rows(rows, _baseline(tmp_path))
    assert [r[0] for r in regs] == ["kernel.b.us"]


def test_throughput_drop_detected(tmp_path):
    rows = [{"name": "kernel.a.us", "value": 100.0, "derived": "x"},
            {"name": "kernel.b.us", "value": 100.0, "derived": "x"},
            {"name": "serve.c_tokens_per_s", "value": 10.0, "derived": "w"}]
    regs = compare_rows(rows, _baseline(tmp_path))
    assert [r[0] for r in regs] == ["serve.c_tokens_per_s"]


def test_changed_workload_rows_are_skipped(tmp_path):
    """A smoke-sized run (different derived string) must not be judged
    against the full-queue baseline."""
    rows = [{"name": "kernel.a.us", "value": 100.0, "derived": "x"},
            {"name": "kernel.b.us", "value": 900.0, "derived": "smoke"},
            {"name": "serve.c_tokens_per_s", "value": 50.0, "derived": "w"}]
    assert compare_rows(rows, _baseline(tmp_path)) == []


def test_even_row_count_cannot_mask_regression(tmp_path):
    """With an even comparable-row count, a slow row in the upper middle
    must not be adopted as the machine speed (true median, not
    upper-middle element)."""
    p = tmp_path / "b.json"
    p.write_text(json.dumps({"suites": [], "rows": [
        {"name": "kernel.a.us", "value": 100.0, "derived": "x"},
        {"name": "kernel.b.us", "value": 100.0, "derived": "x"},
    ]}))
    rows = [{"name": "kernel.a.us", "value": 100.0, "derived": "x"},
            {"name": "kernel.b.us", "value": 300.0, "derived": "x"}]
    regs = compare_rows(rows, str(p))
    assert [r[0] for r in regs] == ["kernel.b.us"]


def test_relative_only_slowdown_is_not_a_regression(tmp_path):
    """A row whose absolute time never grew must not fail just because
    its neighbours sped up more on this box (raw AND normalized ratio
    must both exceed the threshold)."""
    rows = [{"name": "kernel.a.us", "value": 50.0, "derived": "x"},
            {"name": "kernel.b.us", "value": 100.0, "derived": "x"},
            {"name": "serve.c_tokens_per_s", "value": 100.0, "derived": "w"}]
    assert compare_rows(rows, _baseline(tmp_path)) == []


def _sim_baseline(tmp_path):
    p = tmp_path / "sim.json"
    p.write_text(json.dumps({"suites": [], "rows": [
        {"name": "kernel.a.us", "value": 100.0, "derived": "x"},
        {"name": "kernel.b.us", "value": 100.0, "derived": "x"},
        {"name": "gateway.p99_ms", "value": 40.0, "derived": "w, simulated"},
    ]}))
    return str(p)


def test_deterministic_rows_do_not_skew_machine_median(tmp_path):
    """Simulated rows replay at ratio ~1.0 on any machine; they must not
    drag the machine-speed median down and flag a uniformly slower box's
    wall-clock rows as relative regressions."""
    rows = [{"name": "kernel.a.us", "value": 200.0, "derived": "x"},
            {"name": "kernel.b.us", "value": 200.0, "derived": "x"},
            {"name": "gateway.p99_ms", "value": 40.0,
             "derived": "w, simulated"}]
    assert compare_rows(rows, _sim_baseline(tmp_path)) == []


def test_deterministic_row_drift_not_excused_by_slow_box(tmp_path):
    """A >25% move in a deterministic row is a semantic change; machine
    normalization (which would excuse it on a uniformly slow box) must
    not apply."""
    rows = [{"name": "kernel.a.us", "value": 200.0, "derived": "x"},
            {"name": "kernel.b.us", "value": 200.0, "derived": "x"},
            {"name": "gateway.p99_ms", "value": 80.0,
             "derived": "w, simulated"}]
    regs = compare_rows(rows, _sim_baseline(tmp_path))
    assert [r[0] for r in regs] == ["gateway.p99_ms"]


def test_directionless_deterministic_row_gated_symmetrically(tmp_path):
    """A deterministic row without a .us/_ms/per_s direction suffix
    (e.g. adaptive payload bytes) must still be gated — drift in either
    direction is a semantic change to the simulation."""
    p = tmp_path / "d.json"
    p.write_text(json.dumps({"suites": [], "rows": [
        {"name": "kernel.a.us", "value": 100.0, "derived": "x"},
        {"name": "gateway.payload_bytes", "value": 100.0,
         "derived": "w, simulated"},
    ]}))
    grew = [{"name": "kernel.a.us", "value": 100.0, "derived": "x"},
            {"name": "gateway.payload_bytes", "value": 300.0,
             "derived": "w, simulated"}]
    assert [r[0] for r in compare_rows(grew, str(p))] == \
        ["gateway.payload_bytes"]
    shrank = [{"name": "kernel.a.us", "value": 100.0, "derived": "x"},
              {"name": "gateway.payload_bytes", "value": 30.0,
               "derived": "w, simulated"}]
    assert [r[0] for r in compare_rows(shrank, str(p))] == \
        ["gateway.payload_bytes"]
    steady = [{"name": "kernel.a.us", "value": 100.0, "derived": "x"},
              {"name": "gateway.payload_bytes", "value": 101.0,
               "derived": "w, simulated"}]
    assert compare_rows(steady, str(p)) == []


def test_deterministic_ms_row_improvement_is_still_drift(tmp_path):
    """A deterministic latency row that *improves* 2x is just as much a
    semantic change to the seeded simulation as one that regresses —
    the direction suffix must not exempt it."""
    p = tmp_path / "imp.json"
    p.write_text(json.dumps({"suites": [], "rows": [
        {"name": "kernel.a.us", "value": 100.0, "derived": "x"},
        {"name": "gateway.p99_ms", "value": 40.0, "derived": "w, simulated"},
    ]}))
    rows = [{"name": "kernel.a.us", "value": 100.0, "derived": "x"},
            {"name": "gateway.p99_ms", "value": 20.0,
             "derived": "w, simulated"}]
    assert [r[0] for r in compare_rows(rows, str(p))] == ["gateway.p99_ms"]


def test_unknown_rows_are_ignored(tmp_path):
    rows = [{"name": "kernel.new_row.us", "value": 5.0, "derived": "y"},
            {"name": "kernel.errored", "value": "ERROR", "derived": ""}]
    assert compare_rows(rows, _baseline(tmp_path)) == []


def _pct_baseline(tmp_path):
    p = tmp_path / "pct.json"
    p.write_text(json.dumps({"suites": [], "rows": [
        {"name": "kernel.a.us", "value": 100.0, "derived": "x"},
        {"name": "telemetry.overhead_pct", "value": 1.0, "derived": "w"},
    ]}))
    return str(p)


def test_pct_row_gated_on_absolute_ceiling_not_ratio(tmp_path):
    """A _pct row is already a ratio: a jump from 0.5% to 2% is a 4x
    baseline ratio but NOT a regression; crossing the 5% absolute
    ceiling is, even on a uniformly slow box."""
    fine = [{"name": "kernel.a.us", "value": 100.0, "derived": "x"},
            {"name": "telemetry.overhead_pct", "value": 2.0, "derived": "w"}]
    assert compare_rows(fine, _pct_baseline(tmp_path)) == []
    over = [{"name": "kernel.a.us", "value": 200.0, "derived": "x"},
            {"name": "telemetry.overhead_pct", "value": 7.5, "derived": "w"}]
    regs = compare_rows(over, _pct_baseline(tmp_path))
    assert [r[0] for r in regs] == ["telemetry.overhead_pct"]


def test_pct_row_zero_value_still_compared(tmp_path):
    """An overhead of exactly 0.0 must pass (the falsy-value skip that
    protects ratio math from dividing by zero does not apply)."""
    rows = [{"name": "kernel.a.us", "value": 100.0, "derived": "x"},
            {"name": "telemetry.overhead_pct", "value": 0.0, "derived": "w"}]
    assert compare_rows(rows, _pct_baseline(tmp_path)) == []


def test_pct_row_changed_workload_skipped(tmp_path):
    rows = [{"name": "kernel.a.us", "value": 100.0, "derived": "x"},
            {"name": "telemetry.overhead_pct", "value": 50.0,
             "derived": "other-pin"}]
    assert compare_rows(rows, _pct_baseline(tmp_path)) == []
