"""Shared-prefix page cache: bit-identity, refcounts, and the quantized
host tier.

Acceptance claim first: with prefix sharing ON, a workload of clients
sharing a system prompt produces greedy tokens *bit-identical* to
sharing OFF, while the hit rate is deterministic and > 0 and measurably
less prefill work runs.  The sharing machinery (chain-hashed page keys,
refcounted physical pages, seed-the-prefix/prefill-the-suffix
admissions) must be invisible in the tokens because a page's K/V is a
pure function of the token prefix through it — the hash key — and the
suffix chunks attend at the full bucket width, the same
segment-vs-one-shot identity chunked prefill already guarantees.

The cold tier is *lossy by design* (the transmission codec turned
inward), so its tests bound the reconstruction error by the codebook's
step size and check the demote/promote lifecycle instead of
bit-identity; bit-exact runs keep their working set inside
`prefix_hot_pages` (pinned pages never demote, so live slots are always
exact).
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import backbone as bb
from repro.serve.engine import Request
from repro.serve.prefix_cache import PrefixCache, page_keys
from repro.serve.scheduler import ContinuousScheduler, SchedulerConfig

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def system():
    cfg = get_config("qwen2-0.5b").reduced()
    params = bb.init_params(cfg, KEY)
    return cfg, params


def _sched(cfg, params, *, prefix=True, max_len=64, clock=None, **kw):
    base = dict(buckets=(8, 16, 32), max_slots=4, prefill_group=2, chunk=4,
                page_size=8, prefix_cache=prefix)
    base.update(kw)
    return ContinuousScheduler(cfg, params, max_len=max_len, clock=clock,
                               sched=SchedulerConfig(**base))


def _shared_workload(cfg, *, seed=0, sys_len=20, tails=(4, 9, 12, 4, 7, 12,
                                                        3, 9)):
    rng = np.random.RandomState(seed)
    sys_prompt = rng.randint(0, cfg.vocab, sys_len)
    return [Request(tokens=np.concatenate(
                [sys_prompt, rng.randint(0, cfg.vocab, L)]),
                    max_new_tokens=4)
            for L in tails]


def _run(sched, reqs):
    rids = [sched.submit(r) for r in reqs]
    outs = sched.run()
    return [outs[r].tokens.tolist() for r in rids]


# ------------------------------------------------------------ page keys --


def test_page_keys_chain_over_full_prefix():
    """Two prompts share page p's key only when they agree on *every*
    token before the page's end — causal K/V depends on the whole
    prefix, so a same-content page at a different history must not
    collide."""
    a = np.arange(32)
    ka = page_keys(a, 8)
    assert len(ka) == 3                       # page holding token 31 excluded
    b = a.copy()
    b[0] += 1                                 # divergence inside page 0
    kb = page_keys(b, 8)
    assert all(x != y for x, y in zip(ka, kb))
    c = a.copy()
    c[9] += 1                                 # divergence inside page 1
    kc = page_keys(c, 8)
    assert kc[0] == ka[0] and kc[1] != ka[1] and kc[2] != ka[2]


def test_page_keys_exclude_last_token_page():
    """The page holding the final prompt token is never shareable: the
    admission must compute that position itself for its first-token
    logits."""
    assert page_keys(np.arange(8), 8) == []            # T == page
    assert len(page_keys(np.arange(9), 8)) == 1        # page 0 full + final
    assert len(page_keys(np.arange(17), 8)) == 2


# ----------------------------------------------------- acceptance check --


def test_shared_prefix_tokens_bit_identical(system):
    """Acceptance: N clients sharing a system prompt decode the exact
    greedy tokens sharing-off produces, the hit rate is > 0, and a rerun
    reproduces tokens and stats bit-for-bit."""
    cfg, params = system
    reqs = _shared_workload(cfg)
    off = _run(_sched(cfg, params, prefix=False), reqs)
    on_sched = _sched(cfg, params)
    on = _run(on_sched, reqs)
    assert on == off
    pc = on_sched.prefix
    assert pc.hit_rate > 0 and pc.stats["page_hits"] > 0
    again = _sched(cfg, params)
    assert _run(again, reqs) == on
    assert again.prefix.stats == pc.stats          # deterministic hit rate


@pytest.mark.parametrize("overlap", [False, True])
def test_bit_identity_holds_in_both_overlap_modes(system, overlap):
    cfg, params = system
    reqs = _shared_workload(cfg, seed=3)
    off = _run(_sched(cfg, params, prefix=False, overlap=overlap), reqs)
    on = _run(_sched(cfg, params, overlap=overlap), reqs)
    assert on == off


def test_staged_long_prompts_seed_from_shared_pages(system):
    """Long admissions (chunked prefill) seed resident pages and start
    staging past them — tokens stay bit-identical and whole-segment
    seeding registers hits."""
    cfg, params = system
    rng = np.random.RandomState(5)
    sys_prompt = rng.randint(0, cfg.vocab, 24)
    reqs = [Request(tokens=np.concatenate(
                [sys_prompt, rng.randint(0, cfg.vocab, L)]),
                    max_new_tokens=4)
            for L in (12, 20, 12, 6, 20)]     # bucket 48 > segment 16
    kw = dict(buckets=(8, 16, 24, 48), prefill_segment=16, max_len=96)
    off = _run(_sched(cfg, params, prefix=False, **kw), reqs)
    s = _sched(cfg, params, **kw)
    assert _run(s, reqs) == off
    assert s.prefix.hit_rate > 0


def test_prefix_sharing_saves_prefill_work(system):
    """The point of the tentpole: sharing must run measurably less
    prefill.  Counted as prefilled token-positions across the group path
    (rows x bucket) and the chunk path (chunk widths)."""
    cfg, params = system
    reqs = _shared_workload(cfg, seed=4)

    def counted(sched):
        work = {"tok": 0}
        gp, cp = sched._prefill, sched._prefill_chunk

        def prefill(params, tokens, lengths, *, max_len):
            work["tok"] += int(np.sum(np.asarray(lengths)))
            return gp(params, tokens, lengths, max_len=max_len)

        def chunk(params, toks, cache, depth, **kw):
            work["tok"] += toks.shape[0] * toks.shape[1]
            return cp(params, toks, cache, depth, **kw)

        sched._prefill, sched._prefill_chunk = prefill, chunk
        _run(sched, reqs)
        return work["tok"]

    off = counted(_sched(cfg, params, prefix=False))
    on = counted(_sched(cfg, params))
    assert on < off


# ---------------------------------------------------- ownership / refs --


def test_refcounts_pin_during_occupancy_and_release_after(system):
    """While a slot lives, its pages are pinned (a tiny hot budget
    cannot demote them); after run() every ref is dropped and the
    budget is enforced."""
    cfg, params = system
    reqs = _shared_workload(cfg, seed=6)
    sched = _sched(cfg, params, prefix_hot_pages=1, kv_tier_mb=4.0)
    rids = [sched.submit(r) for r in reqs]
    seen_pinned = False
    while sched._queue or sched._staging or sched._slots.any_occupied():
        sched.step()
        pinned = [e for e in sched.prefix._index.values() if e.refs > 0]
        seen_pinned = seen_pinned or bool(pinned)
        assert all(e.hot is not None for e in pinned), \
            "a referenced page must stay device-resident"
    assert seen_pinned
    assert all(e.refs == 0 for e in sched.prefix._index.values())
    assert not sched.prefix._slot_keys
    assert sched.prefix.n_hot <= 1            # budget enforced once unpinned
    assert sorted(sched._results) == sorted(rids)


class _Clock:
    """Deterministic wall clock: every read advances by one tick."""

    def __init__(self, tick: float):
        self.t = 0.0
        self.tick = tick

    def __call__(self) -> float:
        self.t += self.tick
        return self.t


def test_deadline_eviction_releases_refs(system):
    """A pooled slot deadline-evicted mid-decode drops its page refs —
    nothing stays pinned by a dead request."""
    cfg, params = system
    reqs = _shared_workload(cfg, seed=7, tails=(4, 9))
    sched = _sched(cfg, params, max_slots=2, prefill_group=1, chunk=2,
                   clock=_Clock(0.01))
    ra = sched.submit(Request(tokens=reqs[0].tokens, max_new_tokens=40,
                              deadline_s=0.055))
    sched.submit(reqs[1])
    outs = sched.run()
    assert outs[ra].timed_out and 0 < len(outs[ra].tokens) < 40
    assert all(e.refs == 0 for e in sched.prefix._index.values())
    assert not sched.prefix._slot_keys


# ------------------------------------------------------------ cold tier --


def _unit_cache(**kw):
    base = dict(hot_pages=4, cold_bytes=1 << 20, bits=8)
    base.update(kw)
    return PrefixCache(8, **base)


def _fake_rows(rng, n_pages, page=8):
    shape = (2, 1, n_pages * page, 2, 4)      # (n_sb, n_attn, W, n_kv, hd)
    return (rng.standard_normal(shape).astype(np.float32),
            rng.standard_normal(shape).astype(np.float32))


def test_cold_roundtrip_error_bounded_by_codebook_step():
    """Demote -> promote reconstructs every element within half the
    uniform codebook's step over the page's own range."""
    rng = np.random.default_rng(0)
    pc = _unit_cache(hot_pages=0, bits=8)
    keys = page_keys(np.arange(9), 8)
    k, v = _fake_rows(rng, 1)
    pc.pin(0, keys, k, v)
    pc.release(0)                             # unpinned -> demoted cold
    assert pc.n_hot == 0 and pc.n_cold == 1
    assert pc.stats["demotions"] == 1
    got = pc.fetch(keys)
    assert pc.stats["promotions"] == 1
    for orig, rec in ((k, got["k"]), (v, got["v"])):
        ref = orig[:, :, :8]
        step = (ref.max() - ref.min()) / (2 ** 8 - 1)
        assert np.max(np.abs(np.asarray(rec) - ref)) <= step / 2 + 1e-6


def test_promoted_page_keeps_cold_blob_and_never_requantizes():
    """Demote -> promote -> demote again must reuse the original blob
    (re-quantizing a reconstruction would compound the loss)."""
    rng = np.random.default_rng(1)
    pc = _unit_cache(hot_pages=0)
    keys = page_keys(np.arange(9), 8)
    k, v = _fake_rows(rng, 1)
    pc.pin(0, keys, k, v)
    pc.release(0)
    first = pc.fetch(keys)
    pc._enforce_budgets()                     # hot budget 0: demote again
    assert pc.stats["demotions"] == 2
    second = pc.fetch(keys)
    np.testing.assert_array_equal(np.asarray(first["k"]),
                                  np.asarray(second["k"]))


def test_cold_budget_drops_lru_pages():
    """Cold blobs past cold_bytes drop oldest-first; a dropped page is a
    clean miss on the next lookup, never a corrupt hit."""
    rng = np.random.default_rng(2)
    one_page_cold = None
    pc = _unit_cache(hot_pages=0, cold_bytes=1 << 30)
    keys = page_keys(np.arange(9), 8)
    k, v = _fake_rows(rng, 1)
    pc.pin(0, keys, k, v)
    pc.release(0)
    one_page_cold = pc.cold_used_bytes
    assert one_page_cold > 0

    pc = _unit_cache(hot_pages=0, cold_bytes=2 * one_page_cold)
    toks = [np.arange(9) + 100 * i for i in range(3)]
    for i, t in enumerate(toks):
        kk, vv = _fake_rows(rng, 1)
        pc.pin(i, page_keys(t, 8), kk, vv)
        pc.release(i)
    assert pc.cold_used_bytes <= 2 * one_page_cold
    assert pc.stats["cold_drops"] == 1
    assert pc.lookup(toks[0])[1] == 0         # the LRU page is gone
    assert pc.lookup(toks[2])[1] == 1


def test_zero_cold_budget_drops_on_demotion():
    rng = np.random.default_rng(3)
    pc = _unit_cache(hot_pages=0, cold_bytes=0)
    keys = page_keys(np.arange(9), 8)
    k, v = _fake_rows(rng, 1)
    pc.pin(0, keys, k, v)
    pc.release(0)
    assert pc.stats["hot_drops"] == 1
    assert pc.n_hot == pc.n_cold == 0
    assert pc.lookup(np.arange(9))[1] == 0


def test_end_to_end_demote_promote_through_scheduler(system):
    """Two admission waves under a 2-page hot budget: wave B's hits
    promote pages wave A demoted, and every request still completes."""
    cfg, params = system
    rng = np.random.RandomState(8)
    sysp = rng.randint(0, cfg.vocab, 24)
    sched = ContinuousScheduler(
        cfg, params, max_len=64,
        sched=SchedulerConfig(buckets=(8, 16, 32), max_slots=2,
                              prefill_group=2, chunk=4, page_size=8,
                              prefix_cache=True, prefix_hot_pages=2,
                              kv_tier_mb=8.0))

    def wave():
        reqs = [Request(tokens=np.concatenate(
                    [sysp, rng.randint(0, cfg.vocab, 6)]),
                        max_new_tokens=2) for _ in range(3)]
        rids = [sched.submit(r) for r in reqs]
        outs = sched.run()
        assert all(len(outs[r].tokens) == 2 for r in rids)

    wave()
    assert sched.prefix.stats["demotions"] > 0
    wave()
    assert sched.prefix.stats["promotions"] > 0
    assert all(e.refs == 0 for e in sched.prefix._index.values())
