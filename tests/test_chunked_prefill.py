"""Chunked prefill parity: segmented prompt ingestion must be invisible.

`backbone.prefill_chunk` feeds a prompt to the model in fixed token
segments, each attending the same padded width a one-shot prefill would;
the claim — tested bitwise — is that neither the logits nor one K/V cache
element moves, for any segmentation of the same prompt.  On top of that,
the scheduler's staged-admission path (long buckets prefill between
decode chunks) must produce the same greedy tokens as one-shot
admission and per-request decode.
"""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import backbone as bb
from repro.serve.engine import Request, ServeEngine
from repro.serve.scheduler import ContinuousScheduler, SchedulerConfig

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def system():
    cfg = get_config("qwen2-0.5b").reduced()
    params = bb.init_params(cfg, KEY)
    return cfg, params


@pytest.mark.parametrize("T,W,seg", [
    (48, 48, 16),      # even segments, no bucket padding
    (41, 48, 16),      # ragged prompt in a padded bucket
    (48, 48, 48),      # degenerate: one segment
    (33, 64, 8),       # many small segments
])
def test_chunked_prefill_bit_identical(system, T, W, seg):
    """N-segment prefill == one-shot prefill, bit-for-bit, in both the
    last-token logits and every written cache element."""
    cfg, params = system
    rng = np.random.RandomState(T * 100 + seg)
    tokens = rng.randint(0, cfg.vocab, (1, T)).astype(np.int32)

    padded = np.zeros((1, W), np.int32)
    padded[:, :T] = tokens
    oneshot = jax.jit(partial(bb.prefill, cfg), static_argnames=("max_len",))
    logits1, cache1, _ = oneshot(
        params, {"tokens": jnp.asarray(padded)},
        lengths=jnp.asarray([T], jnp.int32), max_len=W)

    n_segs = -(-W // seg)
    chunk = jax.jit(partial(bb.prefill_chunk, cfg),
                    static_argnames=("attend_width",))
    cache2 = bb.init_cache(cfg, 1, n_segs * seg)
    seg_toks = np.zeros((1, n_segs * seg), np.int32)
    seg_toks[:, :T] = tokens
    logits2 = None
    for d in range(0, W, seg):
        last = min(max(T - 1 - d, 0), seg - 1)
        lg, cache2 = chunk(params, jnp.asarray(seg_toks[:, d:d + seg]),
                           cache2, jnp.int32(d), attend_width=W,
                           last_index=jnp.int32(last))
        if d <= T - 1 < d + seg:
            logits2 = lg
        if d + seg >= T:
            break

    np.testing.assert_array_equal(np.asarray(logits1), np.asarray(logits2))
    for nm in ("k", "v"):
        np.testing.assert_array_equal(
            np.asarray(cache1[nm])[:, :, :, :T],
            np.asarray(cache2[nm])[:, :, :, :T])


def test_scheduler_chunked_admission_matches_reference(system):
    """Long prompts admitted through staged (segmented) prefill decode to
    exactly the per-request reference tokens, mixed with short traffic."""
    cfg, params = system
    eng = ServeEngine(cfg, params, max_len=192)   # reference path
    sched_eng = ServeEngine(
        cfg, params, max_len=192,
        scheduler=SchedulerConfig(buckets=(8, 16, 32, 64, 128),
                                  max_slots=4, prefill_group=2, chunk=4,
                                  prefill_segment=32))
    rng = np.random.RandomState(7)
    lens = [100, 8, 16, 97, 8, 128, 16]           # 3 chunked admissions
    reqs = [Request(tokens=rng.randint(0, cfg.vocab, L), max_new_tokens=5)
            for L in lens]
    outs = sched_eng.generate(reqs)
    assert len(outs) == len(reqs)
    for req, got in zip(reqs, outs):
        np.testing.assert_array_equal(got.tokens,
                                      eng.generate([req])[0].tokens)


def test_scheduler_chunked_vs_oneshot_admission(system):
    """The same long-prompt queue with chunked prefill on and off
    completes with identical greedy tokens."""
    cfg, params = system
    rng = np.random.RandomState(8)
    reqs = [Request(tokens=rng.randint(0, cfg.vocab, L), max_new_tokens=4)
            for L in (100, 8, 120, 16)]

    def tokens_with(segment):
        sched = ContinuousScheduler(
            cfg, params, max_len=192,
            sched=SchedulerConfig(buckets=(8, 16, 32, 64, 128),
                                  max_slots=4, prefill_group=2, chunk=4,
                                  prefill_segment=segment))
        rids = [sched.submit(r) for r in reqs]
        outs = sched.run()
        return [outs[r].tokens for r in rids]

    for a, b in zip(tokens_with(32), tokens_with(0)):
        np.testing.assert_array_equal(a, b)


def test_staged_admission_never_stalls_decode(system):
    """While a long prompt stages, short requests keep decoding: the
    scheduler interleaves one prefill segment per round, so the short
    request completes before the long admission finishes staging."""
    cfg, params = system
    sched = ContinuousScheduler(
        cfg, params, max_len=192,
        sched=SchedulerConfig(buckets=(8, 16, 32, 64, 128), max_slots=2,
                              prefill_group=1, chunk=2, prefill_segment=16))
    long_rid = sched.submit(Request(
        tokens=np.arange(128) % cfg.vocab, max_new_tokens=3))
    short_rid = sched.submit(Request(
        tokens=np.arange(8) % cfg.vocab, max_new_tokens=3))
    finished = []
    for _ in range(64):
        finished.extend(sched.step())
        if long_rid in finished:
            break
    assert short_rid in finished and long_rid in finished
    assert finished.index(short_rid) < finished.index(long_rid)
