"""Paged decode attention vs the dense oracle.

The load-bearing claim is *bit*-identity of the blocked-jnp fallback:
`decode_attention` swapped the dense einsum for the paged path in the
serving hot loop, and greedy decode must not move by one ULP.  The Pallas
kernel (online softmax) is held to float tolerance in interpret mode.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.common import auto_page_size
from repro.kernels.decode_attention.ops import (
    paged_decode_attention,
    paged_decode_attention_jnp,
    paged_decode_attention_op,
)
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.nn.attention import (
    attention_decode_apply,
    attention_init,
    decode_attention,
    reference_attention,
)
from tests._hypothesis_compat import given, settings, st

KEY = jax.random.PRNGKey(0)


def _qkv(B, S, Hq, Hkv, D, key=KEY):
    kq, kk, kv = jax.random.split(key, 3)
    return (jax.random.normal(kq, (B, 1, Hq, D)),
            jax.random.normal(kk, (B, S, Hkv, D)),
            jax.random.normal(kv, (B, S, Hkv, D)))


# ------------------------------------------------------ fallback bit-exact --


@pytest.mark.parametrize("B,S,Hq,Hkv,D,page", [
    (4, 1024, 8, 2, 64, 128),
    (2, 256, 4, 4, 32, 64),
    (3, 96, 6, 3, 16, 32),
    (1, 512, 2, 1, 128, 128),
])
def test_paged_jnp_bit_identical_to_dense(B, S, Hq, Hkv, D, page):
    """Every page-prefix branch must reproduce the full-width dense path
    bit-for-bit (masked tail keys are exact zeros in every reduction)."""
    q, k, v = _qkv(B, S, Hq, Hkv, D)
    rng = np.random.RandomState(0)
    for _ in range(4):
        attend = jnp.asarray(rng.randint(1, S + 1, size=B), jnp.int32)
        got = paged_decode_attention_jnp(q, k, v, attend, page_size=page)
        want = decode_attention_ref(q, k, v, attend)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_paged_jnp_scalar_attend_bit_identical():
    q, k, v = _qkv(2, 256, 4, 2, 64)
    for attend in (1, 77, 128, 129, 256):
        got = paged_decode_attention_jnp(q, k, v, attend, page_size=128)
        want = decode_attention_ref(q, k, v, attend)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_decode_attention_dispatch_bit_identical():
    """The public decode_attention (auto page size) == dense oracle, both
    for paging widths and for widths that fall back to dense."""
    for S in (64, 56, 1024):
        q, k, v = _qkv(2, S, 4, 2, 16)
        attend = jnp.asarray([S // 2, S], jnp.int32)
        np.testing.assert_array_equal(
            np.asarray(decode_attention(q, k, v, attend)),
            np.asarray(decode_attention_ref(q, k, v, attend)))


def test_decode_loop_tokens_match_dense_path(monkeypatch):
    """End-to-end pre-PR equivalence: a greedy decode loop through
    bb.decode_step produces the same tokens with the paged path as with
    the dense einsum (the verbatim seed math) forced in its place."""
    from repro.configs import get_config
    from repro.models import backbone as bb
    import repro.nn.attention as attn

    cfg = get_config("qwen2-0.5b").reduced()
    params = bb.init_params(cfg, KEY)
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (2, 8)),
                                   jnp.int32)}
    logits, cache, T = bb.prefill(cfg, params, batch, max_len=64)

    def run():
        step = jax.jit(lambda p, t, c, n: bb.decode_step(cfg, p, t, c, n))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        cl = jnp.full((2,), T, jnp.int32)
        c = cache
        toks = []
        for _ in range(12):
            lg, c = step(params, tok, c, cl)
            tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
            cl = cl + 1
            toks.append(np.asarray(tok))
        return np.concatenate(toks, axis=1)

    got = run()                                    # paged (S=64 pages at 32)
    monkeypatch.setattr(attn, "decode_attention", decode_attention_ref)
    want = run()                                   # seed dense path
    np.testing.assert_array_equal(got, want)


def test_auto_page_size():
    assert auto_page_size(1024) == 128
    assert auto_page_size(64) == 32
    assert auto_page_size(56) == 0      # not page-divisible -> dense
    assert auto_page_size(128) == 64    # >= 2 pages, else nothing to skip


# ------------------------------------------------------- pallas interpret --


@pytest.mark.parametrize("B,S,Hq,Hkv,D,page", [
    (2, 256, 4, 2, 64, 128),
    (1, 512, 8, 4, 64, 128),
    (3, 256, 2, 1, 128, 64),
    (2, 128, 4, 4, 32, 32),
])
def test_pallas_paged_decode_sweep(B, S, Hq, Hkv, D, page):
    q, k, v = _qkv(B, S, Hq, Hkv, D)
    rng = np.random.RandomState(1)
    attend = jnp.asarray(rng.randint(1, S + 1, size=B), jnp.int32)
    got = paged_decode_attention_op(q, k, v, attend, page_size=page,
                                    interpret=True)
    want = decode_attention_ref(q, k, v, attend)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_pallas_paged_decode_bf16():
    q, k, v = _qkv(2, 256, 4, 2, 64)
    q, k, v = (t.astype(jnp.bfloat16) for t in (q, k, v))
    attend = jnp.asarray([100, 256], jnp.int32)
    got = paged_decode_attention_op(q, k, v, attend, page_size=128,
                                    interpret=True)
    assert got.dtype == jnp.bfloat16
    want = decode_attention_ref(q, k, v, attend)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), atol=3e-2, rtol=3e-2)


# ---------------------------------------------- SWA ring / per-row depths --


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=64), min_size=1,
                max_size=6),
       st.integers(min_value=0, max_value=2**31 - 1))
def test_ring_depth_property(depths, seed):
    """Paged and dense decode_attention agree with a per-row oracle built
    from reference_attention across random cache_len vectors, including
    full (ring-wrapped) caches where attend_len == S."""
    S, Hq, Hkv, D = 64, 4, 2, 16
    B = len(depths)
    key = jax.random.PRNGKey(seed % (2**31))
    q, k, v = _qkv(B, S, Hq,Hkv, D, key=key)
    attend = jnp.asarray(depths, jnp.int32)

    paged = paged_decode_attention_jnp(q, k, v, attend, page_size=32)
    dense = decode_attention_ref(q, k, v, attend)
    np.testing.assert_array_equal(np.asarray(paged), np.asarray(dense))

    for b, n in enumerate(depths):     # per-row oracle over the valid prefix
        want = reference_attention(q[b:b + 1], k[b:b + 1, :n],
                                   v[b:b + 1, :n], causal=False)
        np.testing.assert_allclose(paged[b:b + 1], want,
                                   atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_ring_depth_oracle_fixed_seeds(seed):
    """Deterministic twin of the hypothesis property (always runs): random
    per-row depths, including attend_len == S (a full ring), against the
    per-row reference_attention oracle."""
    S, Hq, Hkv, D = 64, 4, 2, 16
    rng = np.random.RandomState(seed)
    B = rng.randint(1, 7)
    depths = rng.randint(1, S + 1, size=B)
    depths[rng.randint(B)] = S          # force a wrapped row
    q, k, v = _qkv(B, S, Hq, Hkv, D, key=jax.random.PRNGKey(seed))
    attend = jnp.asarray(depths, jnp.int32)
    paged = paged_decode_attention_jnp(q, k, v, attend, page_size=32)
    np.testing.assert_array_equal(
        np.asarray(paged), np.asarray(decode_attention_ref(q, k, v, attend)))
    for b, n in enumerate(depths):
        want = reference_attention(q[b:b + 1], k[b:b + 1, :n],
                                   v[b:b + 1, :n], causal=False)
        np.testing.assert_allclose(paged[b:b + 1], want,
                                   atol=2e-5, rtol=2e-5)


def test_swa_ring_wrap_decode_loop():
    """Step-by-step SWA decode through a ring-wrapped cache (paged path,
    S=64 pages at 32) matches windowed full attention — per-row depths
    past the wrap keep attending the whole ring."""
    cfgk = dict(n_heads=4, n_kv_heads=2, head_dim=8)
    d_model, W, T = 32, 64, 80
    params = attention_init(KEY, d_model, 4, 2, 8)
    x = 0.3 * jax.random.normal(KEY, (2, T, d_model))
    from repro.nn.attention import attention_apply
    full = attention_apply(params, x, causal=True, window=W,
                           rope_theta=10000.0, **cfgk)
    k_cache = jnp.zeros((2, W, 2, 8))
    v_cache = jnp.zeros((2, W, 2, 8))
    outs = []
    for t in range(T):
        o, k_cache, v_cache = attention_decode_apply(
            params, x[:, t:t + 1], k_cache, v_cache,
            jnp.asarray([t, t], jnp.int32), rope_theta=10000.0, **cfgk)
        outs.append(o)
    stepped = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(stepped, full, atol=2e-4, rtol=2e-4)
