"""AgileNN core: splitter, combiner, channel selection, deployment fold."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.agilenn_cifar import AgileNNConfig
from repro.configs.base import AgileSpec
from repro.core.agile import agile_forward, agile_predict, init_agile_params
from repro.core.channel_selection import (
    build_mapping_permutation,
    fold_permutation_into_conv,
    permute_reference_stem,
    topk_channel_counts,
)
from repro.core.combiner import alpha_value, combine_predictions, combiner_init
from repro.core.splitter import merge_features, split_features
from repro.models.cnn import extractor_apply, reference_nn_apply, reference_nn_init

KEY = jax.random.PRNGKey(5)

CFG = AgileNNConfig(image_size=16, remote_width=16, remote_blocks=2,
                    reference_width=16, reference_blocks=2,
                    agile=AgileSpec(enabled=True, extractor_channels=24, k=5,
                                    rho=0.8, lam=0.3, ig_steps=2))


def test_split_merge_roundtrip():
    x = jax.random.normal(KEY, (2, 4, 4, 24))
    lo, hi = split_features(x, 5)
    assert lo.shape[-1] == 5 and hi.shape[-1] == 19
    np.testing.assert_allclose(merge_features(lo, hi), x)


def test_combiner_alpha_range_and_gradient_softening():
    p = combiner_init(0.5, temperature=6.0)
    a = alpha_value(p, 6.0)
    np.testing.assert_allclose(float(a), 0.5, atol=1e-6)
    # higher temperature -> smaller |d alpha / d w|
    g4 = jax.grad(lambda w: alpha_value({"w": w}, 4.0))(jnp.asarray(1.0))
    g8 = jax.grad(lambda w: alpha_value({"w": w}, 8.0))(jnp.asarray(1.0))
    assert abs(float(g8)) < abs(float(g4))


def test_combine_predictions_alpha_override():
    lo = jnp.asarray([[1.0, 0.0]])
    hi = jnp.asarray([[0.0, 1.0]])
    p = combiner_init(0.5)
    out = combine_predictions(p, lo, hi, alpha_override=1.0)
    np.testing.assert_allclose(out, lo)
    out = combine_predictions(p, lo, hi, alpha_override=0.0)
    np.testing.assert_allclose(out, hi)


def test_topk_channel_counts():
    imp = jnp.asarray([[0.5, 0.3, 0.1, 0.1], [0.4, 0.4, 0.1, 0.1]])
    counts = topk_channel_counts(imp, k=2)
    np.testing.assert_allclose(np.asarray(counts), [2, 2, 0, 0])


def test_build_mapping_permutation_valid():
    perm = build_mapping_permutation(np.asarray([7, 2, 9]), 12)
    assert sorted(perm.tolist()) == list(range(12))
    assert perm[:3].tolist() == [7, 2, 9]


def test_fold_permutation_matches_take():
    """Folding the mapping into the last conv == explicit permutation."""
    params = init_agile_params(CFG, KEY)
    perm = np.random.RandomState(0).permutation(24)
    x = jax.random.normal(KEY, (2, 16, 16, 3))
    feats = extractor_apply(params["extractor"], x)
    expected = jnp.take(feats, jnp.asarray(perm), axis=-1)
    convs = list(params["extractor"]["convs"])
    convs[-1] = fold_permutation_into_conv(convs[-1], perm)
    folded = extractor_apply({"convs": convs}, x)
    np.testing.assert_allclose(folded, expected, atol=1e-6)


def test_permute_reference_stem_consistency():
    ref = reference_nn_init(KEY, 24, 10, width=16, blocks=2)
    x = jax.random.normal(KEY, (2, 4, 4, 24))
    perm = np.random.RandomState(1).permutation(24)
    mapped = jnp.take(x, jnp.asarray(perm), axis=-1)
    ref2 = permute_reference_stem(ref, perm)
    np.testing.assert_allclose(reference_nn_apply(ref2, mapped),
                               reference_nn_apply(ref, x), atol=1e-5)


def test_agile_forward_shapes_and_alpha():
    params = init_agile_params(CFG, KEY)
    x = jax.random.normal(KEY, (2, 16, 16, 3))
    logits, internals = agile_forward(CFG, params, x, train=True)
    assert logits.shape == (2, 10)
    assert internals["features"].shape[-1] == 24
    assert 0.0 < float(internals["alpha"]) < 1.0
    # eval path (hard quantization) also works
    logits2, _ = agile_predict(CFG, params, x)
    assert logits2.shape == (2, 10)
