"""Fused online offload path: kernel-vs-ref equality, fused-vs-seed
bit-exact parity, byte-identical payload accounting, and the sync-free
engine decode loop structure."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress.lzw import (
    compress_payload,
    lzw_encode,
    lzw_encoded_bytes,
    pack_indices,
    pack_indices_batch,
)
from repro.configs.agilenn_cifar import AgileNNConfig
from repro.configs.base import AgileSpec
from repro.core.agile import (
    agile_forward,
    init_agile_params,
    offload_payload_arrays,
)
from repro.kernels.offload_fused.ops import fused_offload_jnp, fused_offload_op
from repro.kernels.offload_fused.ref import offload_fused_ref
from repro.kernels.quantize.ops import quantize_op
from repro.kernels.quantize.ref import quantize_ref
from repro.kernels.topk_split.ops import split_op
from repro.kernels.topk_split.ref import split_ref
from repro.serve.offload import measure_payload

KEY = jax.random.PRNGKey(7)
CFG = AgileNNConfig(image_size=16, remote_width=16, remote_blocks=2,
                    reference_width=16, reference_blocks=2,
                    agile=AgileSpec(enabled=True, extractor_channels=24, k=5,
                                    rho=0.8, lam=0.3, ig_steps=2))


def _params(shuffled_mapping: bool = True):
    params = init_agile_params(CFG, KEY)
    if shuffled_mapping:
        params["mapping"] = jnp.asarray(
            np.random.RandomState(3).permutation(CFG.extractor_channels),
            jnp.int32)
    return params


# ------------------------------------------------------------ kernel vs ref


@pytest.mark.parametrize("shape,C,k", [((4, 6, 24), 24, 5), ((3, 24), 24, 7),
                                       ((7, 3, 3, 8), 8, 3)])
@pytest.mark.parametrize("L", [4, 8, 16])
def test_fused_kernel_matches_ref(shape, C, k, L):
    x = jax.random.normal(KEY, shape)
    perm = tuple(int(i) for i in np.random.RandomState(0).permutation(C))
    centers = jnp.linspace(-3, 3, L)
    ref = offload_fused_ref(x, centers, perm, k)
    pal = fused_offload_op(x, centers, perm=perm, k=k, interpret=True)
    fb = fused_offload_jnp(x, centers, perm=perm, k=k)
    for r, p, f in zip(ref, pal, fb):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(p))
        np.testing.assert_array_equal(np.asarray(r), np.asarray(f))


@pytest.mark.parametrize("rows", [1, 7, 8, 13, 250, 257])
def test_kernels_accept_ragged_row_counts(rows):
    """The lifted N % block_rows asserts: any row count works."""
    C, k, L = 16, 5, 8
    x = jax.random.normal(KEY, (rows, C))
    perm = tuple(int(i) for i in np.random.RandomState(1).permutation(C))
    centers = jnp.linspace(-2, 2, L)

    l1, r1 = split_op(x, perm=perm, k=k, interpret=True)
    l2, r2 = split_ref(x, perm, k)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))

    i1, d1 = quantize_op(x, centers, interpret=True)
    i2, d2 = quantize_ref(x, centers)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))

    fused = fused_offload_op(x, centers, perm=perm, k=k, interpret=True)
    ref = offload_fused_ref(x, centers, perm, k)
    for f, r in zip(fused, ref):
        np.testing.assert_array_equal(np.asarray(f), np.asarray(r))


# -------------------------------------------------- fused vs seed two-pass


def test_offload_payload_arrays_fused_bitexact():
    params = _params()
    x = jax.random.normal(KEY, (4, 16, 16, 3))
    fused = np.asarray(offload_payload_arrays(CFG, params, x, use_fused=True))
    seed = np.asarray(offload_payload_arrays(CFG, params, x, use_fused=False))
    np.testing.assert_array_equal(fused, seed)


def test_agile_forward_fused_bitexact():
    params = _params()
    x = jax.random.normal(KEY, (4, 16, 16, 3))
    l1, int1 = agile_forward(CFG, params, x, train=False)
    l2, int2 = agile_forward(CFG, params, x, train=False, use_fused=False)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    np.testing.assert_array_equal(np.asarray(int1["features"]),
                                  np.asarray(int2["features"]))


@pytest.mark.parametrize("B", [1, 3, 6])
def test_measure_payload_fused_vs_seed_on_ragged_rows(B):
    """Feature streams whose row count (B * H * W) is not a multiple of
    the kernel tile go through the kernels/common.py pad-to-grid helper;
    payload accounting must agree with the seed two-pass path exactly."""
    from repro.models.cnn import extractor_apply

    params = _params()
    x = jax.random.normal(KEY, (B, 16, 16, 3))
    total_f, idx_f = measure_payload(CFG, params, x, use_fused=True)
    total_s, idx_s = measure_payload(CFG, params, x, use_fused=False)
    assert total_f == total_s
    np.testing.assert_array_equal(idx_f, idx_s)

    # the interpret-mode Pallas kernel on the same ragged row count
    raw = extractor_apply(params["extractor"], x)
    perm = tuple(int(i) for i in np.asarray(params["mapping"]))
    pal = fused_offload_op(raw, params["quant"]["centers"], perm=perm,
                           k=CFG.agile.k, interpret=True)
    ref = offload_fused_ref(raw, params["quant"]["centers"], perm,
                            CFG.agile.k)
    for p, r in zip(pal, ref):
        np.testing.assert_array_equal(np.asarray(p), np.asarray(r))


def test_measure_payload_bytes_identical_to_seed_path():
    """measure_payload (fused + batched pack) == seed per-sample pipeline."""
    params = _params()
    x = jax.random.normal(KEY, (5, 16, 16, 3))
    total, idx = measure_payload(CFG, params, x)

    seed_idx = np.asarray(offload_payload_arrays(CFG, params, x,
                                                 use_fused=False))
    bits = 3                                      # 8-center codebook
    seed_total = 0
    for b in range(seed_idx.shape[0]):
        nbytes, _ = compress_payload(pack_indices(seed_idx[b], bits))
        seed_total += nbytes
    assert total == seed_total
    np.testing.assert_array_equal(idx, seed_idx)


# --------------------------------------------------------- payload codecs


def test_fast_lzw_matches_string_keyed_reference():
    """Dict-of-int encoder == textbook bytes-concatenation LZW."""
    def lzw_encode_naive(data):
        if not data:
            return []
        table = {bytes([i]): i for i in range(256)}
        next_code, out, w = 256, [], bytes([data[0]])
        for b in data[1:]:
            wb = w + bytes([b])
            if wb in table:
                w = wb
            else:
                out.append(table[w])
                table[wb] = next_code
                next_code += 1
                w = bytes([b])
        out.append(table[w])
        return out

    rs = np.random.RandomState(2)
    for n in [0, 1, 5, 300, 3000]:
        data = rs.randint(0, 8, n, dtype=np.uint8).tobytes()
        assert lzw_encode(data) == lzw_encode_naive(data)


def test_lzw_encoded_bytes_closed_form():
    """Segment closed form == the seed per-code width walk."""
    def enc_bytes_naive(n_codes):
        bits, table_size, width = 0, 256, 9
        for _ in range(n_codes):
            bits += width
            table_size += 1
            if table_size >= (1 << width):
                width += 1
        return (bits + 7) // 8

    for n in [0, 1, 255, 256, 257, 768, 769, 5000]:
        assert lzw_encoded_bytes(list(range(n))) == enc_bytes_naive(n)


@pytest.mark.parametrize("bits", [1, 2, 3, 4, 8])
def test_pack_indices_batch_matches_per_sample(bits):
    idx = np.random.RandomState(5).randint(0, 2 ** bits, size=(6, 7, 7, 19))
    batch = pack_indices_batch(idx, bits)
    assert len(batch) == 6
    for b in range(6):
        assert batch[b] == pack_indices(idx[b], bits)
