"""AgileNN split serving on the LM backbones: trains, skews, combines."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import AgileSpec
from repro.core.agile_lm import (
    agile_lm_forward,
    agile_lm_loss,
    extract_token_features,
    init_agile_lm_params,
    offload_payload_bits,
)
from repro.core.skewness import achieved_skewness
from repro.data.synthetic import SyntheticTokens, TokenDatasetSpec
from repro.optim.adamw import adamw_init, adamw_update

KEY = jax.random.PRNGKey(0)


def _cfg(arch="qwen2-0.5b"):
    cfg = get_config(arch).reduced()
    return dataclasses.replace(
        cfg, agile=AgileSpec(enabled=True, extractor_channels=32, k=6,
                             rho=0.7, lam=0.4, ig_steps=4))


def test_forward_shapes():
    cfg = _cfg()
    params = init_agile_lm_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (2, 12), 0, cfg.vocab)
    logits, internals = agile_lm_forward(cfg, params, tokens)
    assert logits.shape == (2, cfg.vocab)
    assert internals["features"].shape == (2, 12, 32)
    assert 0.0 < float(internals["alpha"]) < 1.0
    assert offload_payload_bits(cfg, params, tokens) == 2 * (32 - 6) * 3


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "xlstm-350m", "mixtral-8x7b"])
def test_loss_finite_and_grads_flow(arch):
    cfg = _cfg(arch)
    params = init_agile_lm_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (2, 10), 0, cfg.vocab)
    labels = jax.random.randint(KEY, (2,), 0, cfg.vocab)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: agile_lm_loss(cfg, p, tokens, labels), has_aux=True)(params)
    assert np.isfinite(float(loss))
    for part in ("extractor", "local", "combiner"):
        g = sum(float(jnp.abs(x).sum())
                for x in jax.tree_util.tree_leaves(grads[part]))
        assert np.isfinite(g), part


def test_training_increases_skewness():
    """The paper's core effect on an LM backbone: joint training raises
    the top-k importance mass toward rho."""
    cfg = _cfg()
    data = SyntheticTokens(TokenDatasetSpec(vocab=32, seq_len=12, n_modes=2))
    params = init_agile_lm_params(cfg, KEY)
    opt = adamw_init(params)

    from repro.core.agile_lm import _token_importance

    def measure(p):
        toks = jnp.asarray(data.batch(32, seed=999))
        feats = extract_token_features(p, toks[:, :-1])
        imp = _token_importance(cfg, p["reference"], feats, toks[:, -1],
                                steps=4)
        return float(achieved_skewness(imp, cfg.agile.k))

    @jax.jit
    def step(p, o, toks):
        (loss, m), g = jax.value_and_grad(
            lambda pp: agile_lm_loss(cfg, pp, toks[:, :-1], toks[:, -1]),
            has_aux=True)(p)
        p, o = adamw_update(p, g, o, lr=5e-3, weight_decay=0.0)
        return p, o, loss

    before = measure(params)
    for i in range(100):
        toks = jnp.asarray(data.batch(16, seed=i))
        params, opt, loss = step(params, opt, toks)
    after = measure(params)
    # measured trajectory: 0.21 -> 0.56 over 100 steps (valid-fraction
    # gating keeps the skew signal sparse early on)
    assert after > before + 0.2, (before, after)
    assert after > 0.45, (before, after)
