"""Optional-hypothesis shim: property tests skip cleanly when the package
is absent (fresh checkouts without dev requirements) instead of killing
collection for the whole module."""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st
    try:
        from hypothesis.extra import numpy as hnp
    except ImportError:          # hypothesis without the numpy extra
        hnp = None
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in strategy factory: accepts any attribute/call chain
        (st.lists(...).map(bytes), st.one_of(...)) and keeps returning
        itself — the values are never drawn; the test body is replaced by
        a skip."""

        def __getattr__(self, name):
            return self

        def __call__(self, *a, **k):
            return self

    st = _AnyStrategy()
    hnp = _AnyStrategy()

    def given(*_args, **_kwargs):
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn
