"""Multi-client offload gateway: channel/codec/controller units, fleet
determinism, and bitwise parity of the static path with the per-image
offload runtime."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress.lzw import (
    compress_payload,
    lzw_decode,
    pack_indices,
    pack_indices_batch,
    unpack_indices,
    unpack_indices_batch,
)
from repro.configs.agilenn_cifar import AgileNNConfig
from repro.configs.base import AgileSpec
from repro.core.agile import agile_forward, device_forward, init_agile_params
from repro.serve.gateway import (
    LOSSY_WIFI,
    NARROWBAND,
    WIFI_UDP,
    Channel,
    ChannelConfig,
    ClientSpec,
    Fleet,
    GatewayConfig,
    OffloadGateway,
    RateController,
    default_ladder,
    mixed_fleet,
    requantize,
    subset_centers,
)
from repro.serve.offload import run_offload_inference

KEY = jax.random.PRNGKey(9)
CFG = AgileNNConfig(image_size=16, remote_width=16, remote_blocks=2,
                    reference_width=16, reference_blocks=2,
                    agile=AgileSpec(enabled=True, extractor_channels=24, k=5,
                                    rho=0.8, lam=0.3, ig_steps=2))
PARAMS = init_agile_params(CFG, KEY)


# ------------------------------------------------------------- channel ---

def test_channel_clean_link_closed_form():
    ch = Channel(ChannelConfig(bandwidth_bps=1e6, propagation_s=5e-3), seed=0)
    d = ch.transmit(1250, t_send=1.0)          # 10 kbit at 1 Mbps = 10 ms
    assert d.attempts == 1
    assert d.airtime_s == pytest.approx(0.01)
    assert d.device_free_s == pytest.approx(1.01)
    assert d.arrive_s == pytest.approx(1.015)


def test_channel_full_loss_retransmits_to_cap():
    cfg = ChannelConfig(bandwidth_bps=1e6, drop_prob=1.0,
                        retransmit_timeout_s=0.1, max_attempts=4)
    d = Channel(cfg, seed=0).transmit(1250, t_send=0.0)
    assert d.attempts == 4                     # final attempt delivers
    assert d.airtime_s == pytest.approx(4 * 0.01)
    assert d.device_free_s == pytest.approx(4 * 0.01 + 3 * 0.1)


def test_channel_deterministic_and_lossy_slower():
    a = Channel(LOSSY_WIFI, seed=3)
    b = Channel(LOSSY_WIFI, seed=3)
    da = [a.transmit(200, i * 0.1) for i in range(20)]
    db = [b.transmit(200, i * 0.1) for i in range(20)]
    assert da == db
    clean = Channel(WIFI_UDP, seed=3)
    assert sum(d.airtime_s for d in da) > \
        sum(clean.transmit(200, i * 0.1).airtime_s for i in range(20))


def test_narrowband_slower_than_wifi():
    wifi = Channel(WIFI_UDP, seed=0).transmit(1000, 0.0)
    nb = Channel(NARROWBAND, seed=0).transmit(1000, 0.0)
    assert nb.airtime_s / wifi.airtime_s == pytest.approx(6e6 / 270e3)


# --------------------------------------------------------------- codec ---

@pytest.mark.parametrize("bits", [1, 2, 3, 4, 8])
def test_unpack_indices_roundtrip(bits):
    rng = np.random.RandomState(bits)
    idx = rng.randint(0, 1 << bits, size=(5, 77))
    packed = pack_indices_batch(idx, bits)
    for row, data in zip(idx, packed):
        np.testing.assert_array_equal(
            unpack_indices(data, bits, 77), row)
    np.testing.assert_array_equal(
        unpack_indices_batch(packed, bits, 77), idx)


def test_unpack_survives_lzw_roundtrip():
    rng = np.random.RandomState(0)
    idx = rng.randint(0, 8, size=(4, 4, 19))
    packed = pack_indices(idx, 3)
    nbytes, codes = compress_payload(packed)
    assert 0 < nbytes
    np.testing.assert_array_equal(
        unpack_indices(lzw_decode(codes), 3, idx.size).reshape(idx.shape),
        idx)


# ------------------------------------------------- rate control ladder ---

def test_controller_static_never_moves():
    ctl = RateController(default_ladder(8), slo_s=None)
    for lat in (1.0, 10.0, 0.0):
        ctl.observe(lat)
    assert ctl.level == 0
    assert ctl.profile().bits == 3 and ctl.profile().keep_frac == 1.0


def test_controller_walks_down_and_recovers():
    ladder = default_ladder(8)
    ctl = RateController(ladder, slo_s=0.03)
    for _ in range(10):
        ctl.observe(0.08)                      # sustained SLO violation
    assert ctl.level == len(ladder) - 1
    for _ in range(10):
        ctl.observe(0.001)                     # channel recovered
    assert ctl.level == 0


def test_subset_centers_and_requantize():
    centers = np.asarray(PARAMS["quant"]["centers"], np.float32)
    assert subset_centers(centers, 3) is centers or np.array_equal(
        subset_centers(centers, 3), centers)   # full bits: unchanged
    two = subset_centers(centers, 1)
    assert two.shape == (2,) and two[0] <= two[1]
    # tie resolves to the lowest index, like the fused kernel
    idx = requantize(np.asarray([0.5], np.float32),
                     np.asarray([0.0, 1.0], np.float32))
    assert idx[0] == 0
    # requantize matches the fused full-codebook indices bit-for-bit
    f = np.asarray(jax.random.normal(KEY, (3, 4, 4, 19)), np.float32)
    from repro.compress.quantize import hard_indices
    np.testing.assert_array_equal(
        requantize(f, centers), np.asarray(hard_indices(PARAMS["quant"], f)))


# ------------------------------------------------- device half parity ---

def test_device_forward_matches_agile_forward():
    """The fleet's one batched device pass must reproduce the deployment
    path's local logits exactly (it IS the device half of it)."""
    x = jax.random.normal(KEY, (6, 16, 16, 3))
    local_logits, f_remote, idx = device_forward(CFG, PARAMS, x)
    _, internals = agile_forward(CFG, PARAMS, x, train=False)
    np.testing.assert_array_equal(np.asarray(local_logits),
                                  np.asarray(internals["local_logits"]))
    assert idx.shape == f_remote.shape
    # seed two-pass oracle agrees
    ll2, fr2, idx2 = device_forward(CFG, PARAMS, x, use_fused=False)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx2))
    np.testing.assert_array_equal(np.asarray(local_logits), np.asarray(ll2))


def test_fleet_batched_codec_matches_per_request_reference():
    """The fleet-wide payload cache (one requantize + pack_indices_batch
    + LZW sweep per rate profile) must frame every request byte-identically
    to the per-request reference codec, at the static profile and down
    the rate ladder."""
    specs = mixed_fleet(4, n_requests=3)
    fleet = Fleet(CFG, PARAMS, specs, seed=3)
    ladder = default_ladder(PARAMS["quant"]["centers"].shape[0])
    for prof in ladder:
        if prof.bits >= fleet.full_bits and prof.keep_frac >= 1.0:
            keep = fleet.n_remote
        else:
            keep = max(1, int(round(prof.keep_frac * fleet.n_remote)))
        got = fleet._encoded_rows(prof.bits, keep)
        assert len(got) == fleet.n_requests
        for row in range(fleet.n_requests):
            if prof.bits >= fleet.full_bits and keep >= fleet.n_remote:
                idx = fleet.idx[row]
            else:
                idx = requantize(fleet.f_remote[row][..., :keep],
                                 fleet.centers_for(prof.bits))
            ref_bytes, ref_codes = compress_payload(
                pack_indices(idx, prof.bits))
            assert got[row] == (ref_bytes, ref_codes)
        # second lookup is the cache, not a recompute
        assert fleet._encoded_rows(prof.bits, keep) is got


def test_fleet_payload_cache_hits_across_requests():
    """Repeated sends at one profile reuse the fleet-wide sweep: the
    cache holds exactly the profiles used, and make_payload frames are
    identical across lookups."""
    specs = mixed_fleet(3, n_requests=2)
    fleet = Fleet(CFG, PARAMS, specs, seed=4)
    c = fleet.clients[1]
    p1 = fleet.make_payload(c, 0)
    p2 = fleet.make_payload(c, 0)
    assert (p1.nbytes, p1.codes, p1.count) == (p2.nbytes, p2.codes, p2.count)
    assert set(fleet._payloads) == {(fleet.full_bits, fleet.n_remote)}


# ------------------------------------------------------- gateway runs ---

def _run(specs, *, seed=0, width=4):
    fleet = Fleet(CFG, PARAMS, specs, seed=seed)
    report = OffloadGateway(CFG, PARAMS, fleet,
                            GatewayConfig(batch_width=width)).run()
    return fleet, report


def test_static_gateway_bit_identical_to_per_image_offload():
    """Acceptance: static-configuration gateway logits == the per-image
    `run_offload_inference` path, bitwise, for every request — through
    LZW + bit-pack framing, batching and pool padding."""
    specs = mixed_fleet(6, n_requests=2, channels=(WIFI_UDP, NARROWBAND))
    fleet, report = _run(specs)
    assert len(report.traces) == 12
    for t in report.traces:
        row = fleet.clients[t.client].row0 + t.req
        image = jnp.asarray(fleet.images[row:row + 1])
        ref_logits = np.asarray(
            agile_forward(CFG, PARAMS, image, train=False)[0])[0]
        np.testing.assert_array_equal(t.logits, ref_logits)
        preds, _ = run_offload_inference(CFG, PARAMS, image)
        assert t.pred == int(preds[0])
        assert t.bits == 3 and t.keep == fleet.n_remote


def test_gateway_fixed_seed_determinism():
    """Same-seed fleet runs replay identical latency traces and logits —
    for the static fleet and the adaptive one."""
    for slo in (None, 8.0):
        specs = mixed_fleet(6, n_requests=3, slo_ms=slo)
        _, r1 = _run(specs, seed=5)
        _, r2 = _run(specs, seed=5)
        key1 = [(t.client, t.req, t.t_born, t.t_sent, t.t_arrive, t.t_serve,
                 t.t_done, t.e2e_s, t.energy_j, t.payload_bytes, t.bits,
                 t.keep, t.attempts) for t in r1.traces]
        key2 = [(t.client, t.req, t.t_born, t.t_sent, t.t_arrive, t.t_serve,
                 t.t_done, t.e2e_s, t.energy_j, t.payload_bytes, t.bits,
                 t.keep, t.attempts) for t in r2.traces]
        assert key1 == key2
        assert all(np.array_equal(a.logits, b.logits)
                   for a, b in zip(r1.traces, r2.traces))


def test_gateway_32_client_mixed_fleet_completes():
    """Acceptance: >=32 clients over mixed link rates drive the gateway
    end to end on CPU; every request is served with ordered timestamps
    and closed-form device energy."""
    specs = mixed_fleet(32, n_requests=2)
    fleet, report = _run(specs, width=8)
    assert len(report.traces) == 64
    assert {t.channel for t in report.traces} == \
        {"wifi", "narrowband", "lossy-wifi"}
    t_compute = fleet.compute_time(fleet.clients[0])
    for t in report.traces:
        assert t.t_born <= t.t_sent - t_compute + 1e-12
        assert t.t_sent < t.t_arrive <= t.t_serve < t.t_done
        assert t.e2e_s == pytest.approx(t.t_done - t.t_born)
        c = fleet.clients[t.client]
        ser = Channel(c.spec.channel).serialize_s(t.payload_bytes)
        expect = (c.device.p_cpu_w * t_compute
                  + c.device.p_tx_w * t.attempts * ser)
        assert t.energy_j == pytest.approx(expect)
    assert report.summary()["e2e_p99_ms"] > 0
    assert report.clients_per_s > 0


def test_adaptive_rate_control_sheds_payload():
    """A narrowband client that can never meet a tight SLO walks down
    the ladder; its later payloads are smaller and cheaper than the
    static configuration's."""
    slow = (ClientSpec(channel=NARROWBAND, n_requests=6, slo_ms=10.0),)
    fleet, report = _run(slow, width=2)
    assert fleet.clients[0].controller.level > 0
    static_bytes = report.traces[0].payload_bytes   # first request: level 0
    assert report.traces[0].bits == 3
    last = max(report.traces, key=lambda t: t.req)
    assert last.bits < 3
    assert last.payload_bytes < static_bytes
    assert last.energy_j < report.traces[0].energy_j
    # an un-SLO'd client on the same link never leaves the static profile
    calm = (ClientSpec(channel=NARROWBAND, n_requests=6, slo_ms=None),)
    fleet2, report2 = _run(calm, width=2)
    assert fleet2.clients[0].controller.level == 0
    assert all(t.bits == 3 for t in report2.traces)


def test_gateway_pool_width_does_not_change_logits():
    """Slot-pool width is a throughput knob: the same fleet served at
    width 2 and width 8 produces identical per-request logits (latency
    may differ)."""
    specs = mixed_fleet(5, n_requests=2, channels=(WIFI_UDP,))
    _, narrow = _run(specs, width=2)
    _, wide = _run(specs, width=8)
    a = {(t.client, t.req): t.logits for t in narrow.traces}
    b = {(t.client, t.req): t.logits for t in wide.traces}
    assert a.keys() == b.keys()
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
