"""Optimizers, synthetic data pipeline, checkpoint io."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import restore_checkpoint, save_checkpoint
from repro.data.synthetic import (
    ImageDatasetSpec,
    SyntheticImages,
    SyntheticTokens,
    TokenDatasetSpec,
)
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.schedules import clip_by_global_norm, cosine_schedule
from repro.optim.sgd import sgd_init, sgd_update

KEY = jax.random.PRNGKey(0)


def test_sgd_descends_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = sgd_init(params)
    for _ in range(200):
        grads = jax.tree_util.tree_map(lambda p: 2 * p, params)
        params, opt = sgd_update(params, grads, opt, lr=0.05, weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_descends_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(params)
    for _ in range(300):
        grads = jax.tree_util.tree_map(lambda p: 2 * p, params)
        params, opt = adamw_update(params, grads, opt, lr=0.05, weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_cosine_schedule_shape():
    assert float(cosine_schedule(0, base_lr=1.0, warmup=10, total=100)) == 0.0
    assert abs(float(cosine_schedule(10, base_lr=1.0, warmup=10, total=100)) - 1.0) < 1e-6
    assert float(cosine_schedule(100, base_lr=1.0, warmup=10, total=100)) < 1e-6


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}  # norm 5
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 5.0, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8], rtol=1e-5)


def test_synthetic_images_deterministic_and_learnable_structure():
    data = SyntheticImages(ImageDatasetSpec(image_size=16, noise=0.1))
    x1, y1 = data.batch(8, seed=3)
    x2, y2 = data.batch(8, seed=3)
    np.testing.assert_allclose(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    assert x1.shape == (8, 16, 16, 3)
    # same-class images are more similar than cross-class (structure exists)
    x, y = data.batch(64, seed=0)
    same, cross = [], []
    for i in range(32):
        for j in range(i + 1, 32):
            d = float(np.mean((x[i] - x[j]) ** 2))
            (same if y[i] == y[j] else cross).append(d)
    assert np.mean(same) < np.mean(cross)


def test_synthetic_tokens_markov_structure():
    data = SyntheticTokens(TokenDatasetSpec(vocab=32, seq_len=64, n_modes=2))
    toks = data.batch(4, seed=1)
    assert toks.shape == (4, 64)
    assert toks.min() >= 0 and toks.max() < 32


def test_checkpoint_roundtrip_nested_tuple_tree():
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "blocks": ({"w": jnp.ones((2, 2))}, {"w": jnp.zeros((3,))})}
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ckpt.npz")
        save_checkpoint(path, tree)
        like = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
        restored = restore_checkpoint(path, like)
    np.testing.assert_allclose(restored["a"], tree["a"])
    np.testing.assert_allclose(restored["blocks"][0]["w"], 1.0)
    np.testing.assert_allclose(restored["blocks"][1]["w"], 0.0)
