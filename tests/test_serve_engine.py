"""Serving engine: batched generation, stop handling, determinism."""
import jax
import numpy as np

from repro.configs import get_config
from repro.models import backbone as bb
from repro.serve.engine import Completion, Request, ServeEngine

KEY = jax.random.PRNGKey(0)


def _engine(arch="qwen2-0.5b"):
    cfg = get_config(arch).reduced()
    params = bb.init_params(cfg, KEY)
    return cfg, ServeEngine(cfg, params, max_len=64)


def test_generate_batch_shapes_and_lengths():
    cfg, eng = _engine()
    rng = np.random.RandomState(0)
    reqs = [Request(tokens=rng.randint(0, cfg.vocab, 8), max_new_tokens=5)
            for _ in range(3)]
    outs = eng.generate(reqs)
    assert len(outs) == 3
    for c in outs:
        assert isinstance(c, Completion)
        assert len(c.tokens) == 5
        assert c.tokens.min() >= 0 and c.tokens.max() < cfg.vocab


def test_generate_greedy_deterministic():
    cfg, eng = _engine()
    rng = np.random.RandomState(1)
    prompt = rng.randint(0, cfg.vocab, 8)
    a = eng.generate([Request(tokens=prompt, max_new_tokens=6)])[0]
    b = eng.generate([Request(tokens=prompt, max_new_tokens=6)])[0]
    np.testing.assert_array_equal(a.tokens, b.tokens)


def test_decode_loop_is_single_device_program():
    """The decode phase lowers to one while_loop: no per-token host
    round-trip of logits/tokens inside generation."""
    from functools import partial
    import jax.numpy as jnp
    from repro.serve.engine import _decode_loop

    cfg, eng = _engine()
    batch = {"tokens": jnp.zeros((2, 8), jnp.int32)}
    logits, cache, total_T = bb.prefill(cfg, eng.params, batch, max_len=64)
    jaxpr = jax.make_jaxpr(
        partial(_decode_loop, cfg, buf_len=64, greedy=True))(
        eng.params, logits, cache, total_T, KEY,
        jnp.full((2,), -1, jnp.int32), jnp.full((2,), 6, jnp.int32),
        jnp.int32(6), jnp.float32(1.0))
    prims = {eqn.primitive.name for eqn in jaxpr.eqns}
    assert "while" in prims


def test_generate_respects_per_request_lengths():
    cfg, eng = _engine()
    rng = np.random.RandomState(3)
    prompt = rng.randint(0, cfg.vocab, 8)
    outs = eng.generate([Request(tokens=prompt, max_new_tokens=3),
                         Request(tokens=prompt, max_new_tokens=6)])
    assert len(outs[0].tokens) == 3
    assert len(outs[1].tokens) == 6
    # a 1-token budget holds even inside a larger batch, and an EOS hit
    # on the very first sampled token stops that request immediately
    outs = eng.generate([Request(tokens=prompt, max_new_tokens=1),
                         Request(tokens=prompt, max_new_tokens=6)])
    assert len(outs[0].tokens) == 1
    eos = int(outs[1].tokens[0])
    outs = eng.generate([Request(tokens=prompt, max_new_tokens=6, eos_id=eos),
                         Request(tokens=prompt, max_new_tokens=6)])
    assert outs[0].tokens.tolist() == [eos]
    assert len(outs[1].tokens) == 6


def test_generate_varied_budgets_do_not_recompile():
    """max_new is a traced loop bound: distinct per-call budgets reuse
    one compiled decode program."""
    cfg, eng = _engine()
    rng = np.random.RandomState(4)
    prompt = rng.randint(0, cfg.vocab, 8)
    for n in (3, 5, 7):
        eng.generate([Request(tokens=prompt, max_new_tokens=n)])
    assert eng._loop._cache_size() == 1


def test_per_request_temperatures_in_one_batch():
    """Regression: the engine used requests[0].temperature for the whole
    batch.  A greedy row batched with a sampled row must still produce its
    greedy (argmax) tokens, in one compiled program."""
    cfg, eng = _engine()
    rng = np.random.RandomState(7)
    prompt = rng.randint(0, cfg.vocab, 8)
    ref = eng.generate([Request(tokens=prompt, max_new_tokens=5)])[0]
    outs = eng.generate([
        Request(tokens=prompt, max_new_tokens=5, temperature=0.0),
        Request(tokens=prompt, max_new_tokens=5, temperature=1.4),
    ])
    np.testing.assert_array_equal(outs[0].tokens, ref.tokens)
    assert len(outs[1].tokens) == 5
    assert outs[1].tokens.min() >= 0 and outs[1].tokens.max() < cfg.vocab


def test_extras_key_mismatch_raises():
    """Regression: a batch whose first request carried extras crashed with
    TypeError on the extras-less rows (and extras-less first requests
    silently dropped the others' extras).  Both now raise ValueError."""
    import pytest

    cfg, eng = _engine()
    rng = np.random.RandomState(8)
    prompt = rng.randint(0, cfg.vocab, 8)
    patch = rng.randn(4, 16).astype(np.float32)
    with_ex = Request(tokens=prompt, extras={"patches": patch})
    without = Request(tokens=prompt)
    with pytest.raises(ValueError, match="extras"):
        eng.generate([with_ex, without])
    with pytest.raises(ValueError, match="extras"):
        eng.generate([without, with_ex])


def test_uniform_extras_batch_generates():
    """A batch where every request carries the same extras keys runs the
    vlm prefill path end to end."""
    cfg = get_config("internvl2-1b").reduced()
    params = bb.init_params(cfg, KEY)
    eng = ServeEngine(cfg, params, max_len=64)
    rng = np.random.RandomState(9)
    reqs = [Request(tokens=rng.randint(0, cfg.vocab, 8), max_new_tokens=3,
                    extras={"patches": rng.randn(
                        cfg.vlm.n_patches, cfg.vlm.vision_dim
                    ).astype(np.float32)})
            for _ in range(2)]
    outs = eng.generate(reqs)
    assert [len(c.tokens) for c in outs] == [3, 3]


def test_generate_matches_manual_decode_loop():
    """Engine greedy output == hand-rolled prefill+decode loop."""
    cfg, eng = _engine()
    rng = np.random.RandomState(2)
    prompt = rng.randint(0, cfg.vocab, 8)
    got = eng.generate([Request(tokens=prompt, max_new_tokens=4)])[0].tokens

    import jax.numpy as jnp
    params = eng.params
    batch = {"tokens": jnp.asarray(prompt[None], jnp.int32)}
    logits, cache, total_T = bb.prefill(cfg, params, batch, max_len=64)
    toks = [int(jnp.argmax(logits, -1)[0])]
    cl = total_T
    for _ in range(3):
        t = jnp.asarray([[toks[-1]]], jnp.int32)
        logits, cache = bb.decode_step(cfg, params, t, cache, cl)
        toks.append(int(jnp.argmax(logits, -1)[0]))
        cl += 1
    np.testing.assert_array_equal(got, np.asarray(toks))
