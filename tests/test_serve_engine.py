"""Serving engine: batched generation, stop handling, determinism."""
import jax
import numpy as np

from repro.configs import get_config
from repro.models import backbone as bb
from repro.serve.engine import Completion, Request, ServeEngine

KEY = jax.random.PRNGKey(0)


def _engine(arch="qwen2-0.5b"):
    cfg = get_config(arch).reduced()
    params = bb.init_params(cfg, KEY)
    return cfg, ServeEngine(cfg, params, max_len=64)


def test_generate_batch_shapes_and_lengths():
    cfg, eng = _engine()
    rng = np.random.RandomState(0)
    reqs = [Request(tokens=rng.randint(0, cfg.vocab, 8), max_new_tokens=5)
            for _ in range(3)]
    outs = eng.generate(reqs)
    assert len(outs) == 3
    for c in outs:
        assert isinstance(c, Completion)
        assert len(c.tokens) == 5
        assert c.tokens.min() >= 0 and c.tokens.max() < cfg.vocab


def test_generate_greedy_deterministic():
    cfg, eng = _engine()
    rng = np.random.RandomState(1)
    prompt = rng.randint(0, cfg.vocab, 8)
    a = eng.generate([Request(tokens=prompt, max_new_tokens=6)])[0]
    b = eng.generate([Request(tokens=prompt, max_new_tokens=6)])[0]
    np.testing.assert_array_equal(a.tokens, b.tokens)


def test_generate_matches_manual_decode_loop():
    """Engine greedy output == hand-rolled prefill+decode loop."""
    cfg, eng = _engine()
    rng = np.random.RandomState(2)
    prompt = rng.randint(0, cfg.vocab, 8)
    got = eng.generate([Request(tokens=prompt, max_new_tokens=4)])[0].tokens

    import jax.numpy as jnp
    params = eng.params
    batch = {"tokens": jnp.asarray(prompt[None], jnp.int32)}
    logits, cache, total_T = bb.prefill(cfg, params, batch, max_len=64)
    toks = [int(jnp.argmax(logits, -1)[0])]
    cl = total_T
    for _ in range(3):
        t = jnp.asarray([[toks[-1]]], jnp.int32)
        logits, cache = bb.decode_step(cfg, params, t, cache, cl)
        toks.append(int(jnp.argmax(logits, -1)[0]))
        cl += 1
    np.testing.assert_array_equal(got, np.asarray(toks))
