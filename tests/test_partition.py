"""Partition rules: model-axis placement, divisibility fallbacks, FSDP."""
import re

import pytest

from repro.launch.partition import _RULES, _spec_for


def spec(path, shape, model=16, fsdp=False, dsize=16):
    return _spec_for(path, shape, model,
                     fsdp_axes=("data",) if fsdp else None, fsdp_size=dsize)


def test_embed_vocab_sharded_when_divisible():
    s = spec("embed/table", (151936, 896))
    assert s == ("model", None) or tuple(s) == ("model", None)


def test_embed_fallback_to_dmodel_for_odd_vocab():
    # internvl2: 151655 % 16 != 0 -> shard d_model instead
    s = tuple(spec("embed/table", (151655, 896)))
    assert s == (None, "model")


def test_attention_col_and_row_parallel():
    assert tuple(spec("blocks/0/attn/wq/w", (24, 896, 896))) == (None, None, "model")
    assert tuple(spec("blocks/0/attn/wo/w", (24, 896, 896))) == (None, "model", None)


def test_moe_expert_parallel_when_divisible():
    # arctic: 128 experts / 16 shards
    s = tuple(spec("blocks/0/moe/gate", (35, 128, 7168, 4864)))
    assert s == (None, "model", None, None)


def test_moe_tensor_parallel_fallback_small_expert_count():
    # mixtral: 8 experts < 16 shards -> shard d_ff
    s = tuple(spec("blocks/0/moe/gate", (32, 8, 4096, 14336)))
    assert s == (None, None, None, "model")
    s = tuple(spec("blocks/0/moe/down", (32, 8, 14336, 4096)))
    assert s == (None, None, "model", None)


def test_qwen_attention_head_fallback():
    # qwen2-0.5b: 14 heads * 64 = 896 cols; 896 % 16 == 0 so col-parallel ok
    s = tuple(spec("blocks/0/attn/wq/w", (24, 896, 896)))
    assert "model" in s


def _has_data(s):
    return any(x in ("data", ("data",)) for x in s)


def test_fsdp_adds_data_axis():
    s = tuple(spec("blocks/0/ffn/gate/w", (32, 4096, 14336), fsdp=True))
    assert s.count("model") == 1
    assert _has_data(s)


def test_fsdp_skips_small_tensors():
    s = tuple(spec("blocks/0/norm/scale", (32, 896), fsdp=True))
    assert not _has_data(s)


def test_norms_replicated():
    s = tuple(spec("blocks/0/norm/scale", (24, 896)))
    assert all(x is None for x in s)


def test_every_rule_pattern_is_valid_regex():
    for pattern, candidates in _RULES:
        re.compile(pattern)
        assert all(c >= 1 for c in candidates)
