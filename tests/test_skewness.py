"""Skewness losses (Eq. 1/2) — unit + hypothesis property tests."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st
from _hypothesis_compat import hnp

from repro.core.skewness import (
    achieved_skewness,
    combined_loss,
    descent_loss,
    disorder_loss,
    disorder_rate,
    natural_skewness,
    skewness_loss,
    topk_mass,
)


def _norm(a):
    a = np.abs(a) + 1e-6
    return a / a.sum(-1, keepdims=True)


def test_disorder_loss_zero_when_ordered():
    imp = jnp.asarray([[0.5, 0.3, 0.1, 0.06, 0.04]])
    assert float(disorder_loss(imp, k=2)) == 0.0


def test_disorder_loss_positive_when_violated():
    imp = jnp.asarray([[0.1, 0.3, 0.5, 0.06, 0.04]])
    assert float(disorder_loss(imp, k=2)) > 0.0


def test_skewness_loss_zero_when_met():
    imp = jnp.asarray([[0.6, 0.3, 0.05, 0.05]])
    assert float(skewness_loss(imp, k=2, rho=0.8)) == 0.0


def test_skewness_loss_measures_deficit():
    imp = jnp.asarray([[0.3, 0.3, 0.2, 0.2]])
    np.testing.assert_allclose(float(skewness_loss(imp, k=2, rho=0.8)), 0.2,
                               atol=1e-6)


@given(hnp.arrays(np.float64, (4, 8), elements=st.floats(0.01, 10)))
@settings(max_examples=50, deadline=None)
def test_disorder_loss_nonnegative_and_bounded(raw):
    imp = jnp.asarray(_norm(raw))
    v = float(disorder_loss(imp, k=3))
    assert 0.0 <= v <= 1.0


@given(hnp.arrays(np.float64, (4, 8), elements=st.floats(0.01, 10)),
       st.integers(1, 7), st.floats(0.1, 1.0))
@settings(max_examples=50, deadline=None)
def test_skewness_loss_bounds(raw, k, rho):
    imp = jnp.asarray(_norm(raw))
    v = float(skewness_loss(imp, k=k, rho=rho))
    assert 0.0 <= v <= rho + 1e-9
    # loss + achieved mass >= rho (per-sample identity averaged)
    mass = float(jnp.mean(topk_mass(imp, k)))
    assert v >= rho - mass - 1e-6


@given(hnp.arrays(np.float64, (4, 6), elements=st.floats(0.01, 10)))
@settings(max_examples=30, deadline=None)
def test_descent_loss_zero_iff_sorted(raw):
    imp = jnp.asarray(np.sort(_norm(raw))[:, ::-1].copy())
    assert float(descent_loss(imp)) < 1e-12


def test_combined_loss_lambda_mixing():
    imp = jnp.asarray([[0.3, 0.3, 0.2, 0.2]])
    pred = jnp.asarray(2.0)
    total, m = combined_loss(pred, imp, k=2, rho=0.8, lam=0.3)
    expected = 0.3 * 2.0 + 0.7 * (m["loss_skewness"] + m["loss_disorder"])
    np.testing.assert_allclose(float(total), float(expected), rtol=1e-6)


def test_metrics():
    imp = jnp.asarray([[0.5, 0.3, 0.1, 0.1], [0.1, 0.2, 0.4, 0.3]])
    assert float(achieved_skewness(imp, 2)) == np.float32(0.8 + 0.3) / 2
    assert float(disorder_rate(imp, 2)) == 0.5
    ns = natural_skewness(imp, frac=0.5)
    np.testing.assert_allclose(np.asarray(ns), [0.8, 0.7], rtol=1e-6)
