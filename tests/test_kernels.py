"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(deliverable c: per-kernel allclose against ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention.ops import flash_attention_op
from repro.kernels.attention.ref import attention_ref
from repro.kernels.quantize.ops import quantize_op
from repro.kernels.quantize.ref import quantize_ref
from repro.kernels.rmsnorm.ops import rmsnorm_op
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.topk_split.ops import split_op
from repro.kernels.topk_split.ref import split_ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,T,Hq,Hkv,D", [
    (1, 128, 4, 2, 64),
    (2, 256, 2, 2, 32),
    (1, 128, 8, 4, 128),
    (1, 384, 2, 1, 64),
])
@pytest.mark.parametrize("window", [0, 64])
def test_pallas_attention_sweep(B, T, Hq, Hkv, D, window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, T, Hq, D))
    k = jax.random.normal(ks[1], (B, T, Hkv, D))
    v = jax.random.normal(ks[2], (B, T, Hkv, D))
    out = flash_attention_op(q, k, v, causal=True, window=window,
                             q_block=128, kv_block=128, interpret=True)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=True,
                        window=window).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_pallas_attention_bf16():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 128, 2, 64)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 128, 2, 64)).astype(jnp.bfloat16)
    out = flash_attention_op(q, k, v, interpret=True)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), atol=3e-2, rtol=3e-2)
    assert out.dtype == jnp.bfloat16


@pytest.mark.parametrize("shape", [(2, 7, 7, 19), (128,), (3, 100), (4, 8, 24)])
@pytest.mark.parametrize("L", [4, 8, 16])
def test_pallas_quantize_sweep(shape, L):
    x = jax.random.normal(KEY, shape)
    centers = jnp.linspace(-3, 3, L)
    i1, d1 = quantize_op(x, centers, interpret=True)
    i2, d2 = quantize_ref(x, centers)
    np.testing.assert_array_equal(i1, i2)
    np.testing.assert_allclose(d1, d2)


@pytest.mark.parametrize("shape,d", [((3, 5, 256), 256), ((2, 128), 128),
                                     ((1, 9, 384), 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_rmsnorm_sweep(shape, d, dtype):
    x = jax.random.normal(KEY, shape).astype(dtype)
    sc = (1.0 + 0.1 * jax.random.normal(KEY, (d,))).astype(dtype)
    y1 = rmsnorm_op(x, sc, interpret=True)
    y2 = rmsnorm_ref(x, sc)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(y1.astype(jnp.float32),
                               y2.astype(jnp.float32), atol=tol, rtol=tol)
    assert y1.dtype == dtype


@pytest.mark.parametrize("C,k", [(24, 5), (24, 7), (8, 3)])
def test_pallas_split_sweep(C, k):
    x = jax.random.normal(KEY, (4, 6, C))
    perm = tuple(int(i) for i in np.random.RandomState(0).permutation(C))
    l1, r1 = split_op(x, perm=perm, k=k, interpret=True)
    l2, r2 = split_ref(x, perm, k)
    np.testing.assert_allclose(l1, l2)
    np.testing.assert_allclose(r1, r2)
    assert l1.shape[-1] == k and r1.shape[-1] == C - k
