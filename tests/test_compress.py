"""LZW codec, bit packing, learned quantizer: unit + hypothesis."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.compress.lzw import (
    compress_payload,
    lzw_decode,
    lzw_encode,
    lzw_encoded_bytes,
    pack_indices,
)
from repro.compress.quantize import (
    dequantize,
    hard_indices,
    quantization_bits,
    quantize_ste,
    quantizer_init,
    soft_quantize,
)


@given(st.binary(min_size=0, max_size=2000))
@settings(max_examples=60, deadline=None)
def test_lzw_roundtrip(data):
    assert lzw_decode(lzw_encode(data)) == data


def test_lzw_compresses_repetitive_data():
    data = b"abab" * 500
    nbytes, _ = compress_payload(data)
    assert nbytes < len(data) / 4


def test_lzw_encoded_bytes_nonzero():
    assert lzw_encoded_bytes(lzw_encode(b"hello world")) > 0
    assert lzw_encoded_bytes([]) == 0


@pytest.mark.parametrize("bits", [1, 2, 3, 4, 8])
def test_pack_indices_size(bits):
    idx = np.random.RandomState(0).randint(0, 2 ** bits, size=257)
    packed = pack_indices(idx, bits)
    assert len(packed) == -(-257 * bits // 8)


def test_quantizer_roundtrip_on_centers():
    q = quantizer_init(8, -4, 4)
    x = q["centers"]
    idx = hard_indices(q, x)
    np.testing.assert_array_equal(np.asarray(idx), np.arange(8))
    np.testing.assert_allclose(dequantize(q, idx), x)


def test_soft_quantize_approaches_hard_at_low_temp():
    q = quantizer_init(8, -4, 4)
    x = jnp.asarray([0.3, -1.2, 2.7])
    soft = soft_quantize(q, x, temperature=1e-4)
    hard = dequantize(q, hard_indices(q, x))
    np.testing.assert_allclose(soft, hard, atol=1e-3)


def test_quantize_ste_gradient_passthrough():
    import jax
    q = quantizer_init(8, -4, 4)
    x = jnp.asarray([0.3, -1.2, 2.7])
    g = jax.grad(lambda xx: jnp.sum(quantize_ste(q, xx)))(x)
    # straight-through: gradient flows (soft path), not zero
    assert float(jnp.abs(g).min()) > 0.0


def test_quantization_bits():
    assert quantization_bits(8) == 3
    assert quantization_bits(16) == 4
    assert quantization_bits(2) == 1
