"""Integration: lower+compile reduced configs on a small placeholder mesh.

Runs in a subprocess so the host-device-count flag never leaks into the
main test process (mirrors how repro.launch.dryrun isolates it).
"""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import dataclasses
from repro.configs import get_config
from repro.configs.shapes import get_shape
from repro.launch.steps import Strategy, lower_step
from repro.roofline.analysis import analyze_compiled

mesh = jax.make_mesh((2, 4), ("data", "model"))
out = []
for arch, shape_name, strat in [
    ("qwen2-0.5b", "train_4k", None),
    ("mixtral-8x7b", "decode_32k", None),
    ("jamba-1.5-large-398b", "prefill_32k", None),
    ("qwen2-0.5b", "train_4k", Strategy(model_axes=(), fsdp=False)),
]:
    cfg = get_config(arch).reduced()
    shape = dataclasses.replace(get_shape(shape_name), global_batch=8,
                                seq_len=64)
    lowered, meta = lower_step(cfg, shape, mesh, strategy=strat)
    compiled = lowered.compile()
    rec = analyze_compiled(compiled, mesh=mesh)
    out.append({"arch": arch, "shape": shape_name,
                "strategy": "opt" if strat else "base",
                "counts": rec["collectives"]["counts"]})
print(json.dumps(out))
"""


@pytest.mark.parametrize("dummy", [0])
def test_lower_compile_small_mesh(dummy):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=480)
    assert proc.returncode == 0, proc.stderr[-3000:]
    recs = json.loads(proc.stdout.strip().splitlines()[-1])
    assert len(recs) == 4
    base = next(r for r in recs
                if r["arch"] == "qwen2-0.5b" and r["strategy"] == "base")
    opt = next(r for r in recs
               if r["arch"] == "qwen2-0.5b" and r["strategy"] == "opt")
    # the H1-style pure-DP strategy must eliminate the gathers/all-to-alls
    assert opt["counts"]["all-gather"] < base["counts"]["all-gather"] or \
        sum(opt["counts"].values()) < sum(base["counts"].values())
