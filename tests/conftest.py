import os
import sys

# tests run against the single real CPU device (the 512-device flag lives
# ONLY in repro.launch.dryrun).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)
