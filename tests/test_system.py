"""End-to-end system tests: the full AgileNN pipeline (stages A-D) on
synthetic data must reproduce the paper's qualitative claims, and the LM
backbone must train (loss decreases) on synthetic token data."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.agilenn_cifar import AgileNNConfig
from repro.configs.base import AgileSpec

CFG = AgileNNConfig(image_size=16, remote_width=24, remote_blocks=2,
                    reference_width=32, reference_blocks=3,
                    agile=AgileSpec(enabled=True, extractor_channels=24, k=5,
                                    rho=0.8, lam=0.3, ig_steps=4))


@pytest.fixture(scope="module")
def pipeline_result():
    from repro.train.agile_pipeline import run_full_pipeline
    return run_full_pipeline(CFG, pretrain_steps=60, joint_steps=120,
                             batch_size=32, xai_method="ig")


def test_pipeline_accuracy(pipeline_result):
    _, _, report, _, _ = pipeline_result
    assert report["reference_accuracy"] > 0.9
    assert report["accuracy"] > 0.85       # paper: accuracy preserved


def test_pipeline_skewness_objective(pipeline_result):
    """§7.4: achieved skewness meets the rho requirement within a few %."""
    _, _, report, _, _ = pipeline_result
    assert report["skewness"] > CFG.agile.rho - 0.08, report


def test_pipeline_disorder_rate(pipeline_result):
    """§4.1: disorder cases pushed to a small fraction (paper: <2%; we
    allow <12% at this tiny training budget)."""
    _, _, report, _, _ = pipeline_result
    assert report["disorder_rate"] < 0.12, report


def test_deployment_finalize_preserves_predictions(pipeline_result):
    from repro.core.agile import agile_predict
    params, ref_params, report, history, data = pipeline_result
    images, labels = data.batch(32, seed=777)
    logits, _ = agile_predict(CFG, params, images)
    acc = float(jnp.mean((jnp.argmax(logits, -1) == labels)))
    assert acc > 0.85


def test_alpha_not_collapsed(pipeline_result):
    """§3.3: the T-softened sigmoid keeps alpha away from 0/1."""
    params, _, _, _, _ = pipeline_result
    from repro.core.combiner import alpha_value
    a = float(alpha_value(params["combiner"], CFG.agile.alpha_temperature))
    assert 0.02 < a < 0.98


def test_lm_backbone_trains_on_synthetic_tokens():
    """A reduced LLM config trains for 30 steps and reduces loss."""
    from repro.configs import get_config
    from repro.data.synthetic import SyntheticTokens, TokenDatasetSpec
    from repro.models import backbone as bb
    from repro.optim.adamw import adamw_init, adamw_update

    cfg = get_config("qwen2-0.5b").reduced()
    # effective vocab 32 (< model vocab 512) so the Markov transition table
    # is learnable within a 50-step CPU budget
    data = SyntheticTokens(TokenDatasetSpec(vocab=32, seq_len=32, n_modes=2))
    key = jax.random.PRNGKey(0)
    params = bb.init_params(cfg, key)
    opt = adamw_init(params)

    @jax.jit
    def step(p, o, tokens):
        batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

        def loss_fn(pp):
            return bb.forward_loss(cfg, pp, batch)[0]

        loss, grads = jax.value_and_grad(loss_fn)(p)
        p, o = adamw_update(p, grads, o, lr=1e-2, weight_decay=0.0)
        return p, o, loss

    losses = []
    for i in range(50):
        toks = jnp.asarray(data.batch(16, seed=i))
        params, opt, loss = step(params, opt, toks)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 2.0, losses[::10]
    assert np.isfinite(losses[-1])
