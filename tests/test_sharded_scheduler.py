"""Mesh-sharded slot pool: parity with the single-device scheduler.

Runs in a subprocess so `--xla_force_host_platform_device_count=8` is set
before JAX imports and never leaks into the main test process (the same
isolation as test_dryrun_small_mesh).  The claims:

  * greedy tokens from the data-sharded pool are *bit-identical* to the
    single-device scheduler's, overlap on or off;
  * the pool's integer state (buf/gen/done/tok/cache_len) is bit-identical
    too; the float K/V cache matches to GEMM-reassociation tolerance —
    per-shard rows multiply at a different M-shape, the same ULP class
    that separates B=1 from B=8 matmuls on one device (the seed
    scheduler's own cache differs from per-request decode the same way);
  * two identical sharded runs are bitwise deterministic, cache included;
  * a tensor-parallel (4, 2) mesh actually shards params over the model
    axis and serves deterministically;
  * inject lands a request's rows on the data shard that owns its slot,
    and evict resets that shard's cache_len.
"""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import numpy as np
from repro.configs import get_config
from repro.models import backbone as bb
from repro.launch.mesh import make_serving_mesh
from repro.serve.engine import Request
from repro.serve.scheduler import ContinuousScheduler, SchedulerConfig

cfg = get_config("qwen2-0.5b").reduced()
params = bb.init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.RandomState(0)
lens = [8, 16, 32, 5, 11, 27, 8, 16, 32, 8]
reqs = [Request(tokens=rng.randint(0, cfg.vocab, L), max_new_tokens=4)
        for L in lens]
KW = dict(buckets=(8, 16, 32), max_slots=8, prefill_group=4, chunk=4)
checks = {}


def run(mesh, overlap=True):
    sched = ContinuousScheduler(cfg, params, max_len=64, mesh=mesh,
                                sched=SchedulerConfig(overlap=overlap, **KW))
    rids = [sched.submit(r) for r in reqs]
    outs = sched.run()
    toks = [outs[r].tokens.tolist() for r in rids]
    pool = jax.tree.map(np.asarray, sched._pool)
    return toks, pool, sched


ref_toks, ref_pool, _ = run(None)
mesh = make_serving_mesh(data=8, model=1)
sh_toks, sh_pool, _ = run(mesh)

checks["tokens_bit_identical"] = sh_toks == ref_toks
checks["int_state_bit_identical"] = all(
    np.array_equal(ref_pool[k], sh_pool[k])
    for k in ("buf", "gen", "done", "tok", "cache_len", "eos", "max_new"))
checks["cache_allclose"] = all(
    np.allclose(ref_pool["cache"][k], sh_pool["cache"][k],
                rtol=1e-5, atol=1e-5) for k in ("k", "v"))

sh2_toks, sh2_pool, _ = run(mesh)
checks["sharded_deterministic"] = sh2_toks == sh_toks and all(
    np.array_equal(a, b) for a, b in
    zip(jax.tree.leaves(sh_pool), jax.tree.leaves(sh2_pool)))

ser_toks, _, _ = run(mesh, overlap=False)
checks["serialized_tokens_bit_identical"] = ser_toks == ref_toks

# tensor-parallel mesh: params sharded over the model axis, runs twice
# to the same tokens (bitwise cache identity is a data-parallel-only
# claim: row-parallel matmuls psum across model shards)
tp = make_serving_mesh(data=4, model=2)
tp_toks, _, tp_sched = run(tp)
tp2_toks, _, _ = run(tp)
checks["tp_deterministic"] = tp_toks == tp2_toks
checks["tp_budgets"] = all(len(t) == 4 for t in tp_toks)
checks["tp_params_model_sharded"] = any(
    "model" in str(getattr(l.sharding, "spec", ""))
    for l in jax.tree.leaves(tp_sched.params))

# ---- evict/inject shard placement -----------------------------------
sched = ContinuousScheduler(cfg, params, max_len=64, mesh=mesh,
                            sched=SchedulerConfig(overlap=False, **KW))
rid = sched.submit(Request(tokens=np.arange(8) % cfg.vocab,
                           max_new_tokens=30))
sched.step()
slot = sched._slot_rid.index(rid)
shards = sched._pool["buf"].addressable_shards
checks["pool_slot_axis_sharded"] = (
    len(shards) == 8 and all(s.data.shape[0] == 1 for s in shards))


def shard_row(arr, slot):
    for s in arr.addressable_shards:
        sl = s.index[0]
        if sl.start <= slot < sl.stop:
            return np.asarray(s.data), slot - sl.start, sl
    raise AssertionError("no shard owns the slot")


cl_local, off, sl = shard_row(sched._pool["cache_len"], slot)
checks["inject_lands_on_owning_shard"] = (
    cl_local.shape[0] == 1                       # 8 slots over 8 shards
    and int(cl_local[off]) == 8 + sched.sched.chunk)   # prompt + 1 chunk
buf_local, off_b, _ = shard_row(sched._pool["buf"], slot)
buf_global = np.asarray(sched._pool["buf"])
checks["shard_holds_its_rows"] = bool(
    np.array_equal(buf_local[off_b], buf_global[slot]))
sched.run()
cl_local, off, _ = shard_row(sched._pool["cache_len"], slot)
checks["evict_resets_owning_shard"] = int(cl_local[off]) == 0

print(json.dumps(checks))
"""


@pytest.mark.parametrize("dummy", [0])
def test_sharded_pool_matches_single_device(dummy):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    checks = json.loads(proc.stdout.strip().splitlines()[-1])
    bad = [k for k, v in checks.items() if not v]
    assert not bad, f"failed checks: {bad} ({checks})"
