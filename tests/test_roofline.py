"""Roofline machinery: HLO collective parser, analytic model, strategies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.shapes import get_shape
from repro.roofline.analysis import collective_bytes_from_hlo, model_flops
from repro.roofline.analytic import (
    MeshSpec,
    analytic_roofline,
    flops_estimate,
    strategy_roofline,
    total_param_count,
)


def test_collective_parser_counts_and_bytes():
    hlo = """
  %ag = f32[16,1024]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = bf16[8,8]{1,0} all-reduce(%y), to_apply=%sum
  %nothing = f32[4]{0} add(%a, %b)
  %a2a = f32[2,2]{1,0} all-to-all(%z)
"""
    out = collective_bytes_from_hlo(hlo)
    assert out["counts"]["all-gather"] == 1
    assert out["counts"]["all-reduce"] == 1
    assert out["counts"]["all-to-all"] == 1
    assert out["bytes_by_kind"]["all-gather"] == 16 * 1024 * 4
    assert out["bytes_by_kind"]["all-reduce"] == 8 * 8 * 2
    assert out["total_bytes"] == 16 * 1024 * 4 + 64 * 2 + 4 * 4


def test_param_counts_sane():
    # qwen2-0.5b ~0.5B params; mixtral total ~47B with 8 experts
    q = total_param_count(get_config("qwen2-0.5b"))
    assert 3e8 < q < 8e8, q
    m = total_param_count(get_config("mixtral-8x7b"))
    assert 4e10 < m < 6e10, m
    jam = total_param_count(get_config("jamba-1.5-large-398b"))
    assert 2.5e11 < jam < 6e11, jam


def test_flops_train_vs_prefill_ratio():
    cfg = get_config("llama3.2-1b")
    tr = flops_estimate(cfg, get_shape("train_4k"))
    pf = flops_estimate(cfg, get_shape("prefill_32k"))
    assert tr > 0 and pf > 0
    # train has the 3x fwd+bwd multiplier but prefill's causal attention
    # context is 8x longer (32k vs 4k), so the ratio lands between them
    assert 1.5 < tr / pf < 4.0


def test_decode_flops_tiny_vs_prefill():
    cfg = get_config("qwen2-0.5b")
    dec = flops_estimate(cfg, get_shape("decode_32k"))
    pf = flops_estimate(cfg, get_shape("prefill_32k"))
    assert dec < pf / 1000


def test_strategy_roofline_h1_direction():
    """Pure DP must beat TP-16 for a 0.5B model (the H1 hillclimb)."""
    cfg, sh = get_config("qwen2-0.5b"), get_shape("train_4k")
    base = strategy_roofline(cfg, sh, tp=16, fsdp=True, n_micro=1)
    opt = strategy_roofline(cfg, sh, tp=1, fsdp=False,
                            replicated_params=True, n_micro=1)
    assert opt["step_s_bound"] < base["step_s_bound"] / 3


def test_strategy_roofline_h3_direction():
    """All-chip TP must beat gathered 2D weights for 398B decode (H3)."""
    cfg, sh = get_config("jamba-1.5-large-398b"), get_shape("decode_32k")
    base = strategy_roofline(cfg, sh, tp=16, fsdp=True)
    opt = strategy_roofline(cfg, sh, tp=256, fsdp=False)
    assert opt["step_s_bound"] < base["step_s_bound"] / 20


def test_strategy_roofline_h2_direction():
    """Resident experts must beat FSDP-gathered experts (H2)."""
    cfg, sh = get_config("arctic-480b"), get_shape("train_4k")
    base = strategy_roofline(cfg, sh, tp=16, fsdp=True, n_micro=16)
    opt = strategy_roofline(cfg, sh, tp=16, fsdp=True, n_micro=4,
                            expert_resident=True)
    assert opt["step_s_bound"] < base["step_s_bound"] / 5


def test_analytic_roofline_terms_positive():
    mesh = MeshSpec()
    for arch in ("qwen2-0.5b", "mixtral-8x7b", "xlstm-350m"):
        cfg = get_config(arch)
        for sname in ("train_4k", "decode_32k"):
            r = analytic_roofline(cfg, get_shape(sname), mesh)
            assert r["compute_s"] > 0
            assert r["memory_s"] > 0
            assert r["dominant"] in ("compute_s", "memory_s", "collective_s")


def test_model_flops_scaling():
    cfg = get_config("qwen2-0.5b")
    tr = model_flops(cfg, get_shape("train_4k"))
    assert tr == 6.0 * __import__("repro.roofline.analysis",
                                  fromlist=["active_param_count"]
                                  ).active_param_count(cfg) * 256 * 4096
