"""Fault injection and graceful degradation across the serving stack.

Three layers of claims: the channel's bounded-retry/backoff/deadline
arithmetic (closed forms), the gateway's degradation ladder (fault-free
runs bit-identical with an idle injector attached; a total blackout
resolves EVERY request as a Local-NN fallback whose logits match the
standalone local path bitwise; corruption degrades to the ERASED floor,
never crashes), and the decode scheduler's deadline eviction (a stalled
slot pool cannot hang `run()`)."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress.lzw import (
    PayloadCorruptionError,
    compress_payload,
    lzw_decode,
    pack_indices,
    packed_nbytes,
    unpack_indices,
    unpack_indices_batch,
)
from repro.configs.agilenn_cifar import AgileNNConfig
from repro.configs.base import AgileSpec
from repro.core.agile import (
    agile_forward, init_agile_params, remote_forward_jit,
)
from repro.serve.faults import (
    Blackout,
    BurstLoss,
    DeviceStall,
    FaultInjector,
    GatewayStall,
    LinkDegrade,
    PayloadCorruption,
    SlotPoolStall,
    parse_faults,
)
from repro.serve.gateway import (
    NARROWBAND,
    WIFI_UDP,
    Channel,
    ChannelConfig,
    ClientSpec,
    Fleet,
    GatewayConfig,
    OffloadGateway,
    mixed_fleet,
)
from repro.serve.gateway.channel import RETRY_SAFETY_CAP
from repro.serve.scheduler import SlotError, SlotPool

KEY = jax.random.PRNGKey(9)
CFG = AgileNNConfig(image_size=16, remote_width=16, remote_blocks=2,
                    reference_width=16, reference_blocks=2,
                    agile=AgileSpec(enabled=True, extractor_channels=24, k=5,
                                    rho=0.8, lam=0.3, ig_steps=2))
PARAMS = init_agile_params(CFG, KEY)


# ------------------------------------------------- parameter validation ---

@pytest.mark.parametrize("kw", [
    {"bandwidth_bps": -1.0}, {"bandwidth_bps": 0.0},
    {"propagation_s": -1e-3}, {"jitter_s": -1e-3},
    {"drop_prob": -0.1}, {"drop_prob": 1.5},
    {"retransmit_timeout_s": 0.0}, {"retransmit_timeout_s": -0.1},
    {"max_attempts": -1}, {"backoff_mult": 0.5},
    {"backoff_max_s": 0.0}, {"backoff_jitter": -0.1},
])
def test_channel_config_rejects_bad_params(kw):
    with pytest.raises(ValueError, match=next(iter(kw))):
        ChannelConfig(**kw)


@pytest.mark.parametrize("kw", [
    {"channel": "wifi"}, {"arrival_rate_hz": 0.0},
    {"arrival_rate_hz": -5.0}, {"n_requests": -1},
    {"slo_ms": 0.0}, {"deadline_ms": 0.0}, {"deadline_ms": -10.0},
])
def test_client_spec_rejects_bad_params(kw):
    with pytest.raises(ValueError, match=next(iter(kw))):
        ClientSpec(**kw)


@pytest.mark.parametrize("make", [
    lambda: Blackout(0.2, 0.1),
    lambda: BurstLoss(p_good_bad=1.5),
    lambda: LinkDegrade(bandwidth_scale=0.0),
    lambda: LinkDegrade(extra_loss=2.0),
    lambda: DeviceStall(stall_s=0.0),
    lambda: GatewayStall(stall_s=-1.0),
    lambda: PayloadCorruption(prob=0.0),
    lambda: SlotPoolStall(5, 5),
])
def test_fault_events_reject_bad_params(make):
    with pytest.raises(ValueError):
        make()


def test_gateway_config_rejects_bad_params():
    with pytest.raises(ValueError, match="batch_width"):
        GatewayConfig(batch_width=0)
    with pytest.raises(ValueError, match="batch_window_s"):
        GatewayConfig(batch_window_s=-1e-3)


def test_injector_rejects_unknown_event():
    with pytest.raises(ValueError, match="unknown fault event"):
        FaultInjector(("not-a-fault",))


# -------------------------------------------- channel retry arithmetic ---

def test_backoff_waits_closed_form():
    """mult=2 doubles the retry wait, capped at backoff_max_s; the default
    mult=1.0 reproduces the fixed timeout bit-exactly."""
    cfg = ChannelConfig(bandwidth_bps=1e6, drop_prob=1.0, max_attempts=5,
                        retransmit_timeout_s=0.1, backoff_mult=2.0,
                        backoff_max_s=0.3, propagation_s=0.0)
    d = Channel(cfg, seed=0).transmit(1250, t_send=0.0)   # ser = 10 ms
    assert d.attempts == 5 and d.delivered
    # waits: 0.1, 0.2, min(0.4, 0.3), min(0.8, 0.3)
    assert d.device_free_s == pytest.approx(5 * 0.01 + 0.1 + 0.2 + 0.3 + 0.3)
    fixed = ChannelConfig(bandwidth_bps=1e6, drop_prob=1.0, max_attempts=3,
                          retransmit_timeout_s=0.1)
    df = Channel(fixed, seed=0).transmit(1250, t_send=0.0)
    assert df.device_free_s == pytest.approx(3 * 0.01 + 2 * 0.1)


def test_backoff_jitter_bounded_and_deterministic():
    cfg = ChannelConfig(bandwidth_bps=1e6, drop_prob=1.0, max_attempts=4,
                        retransmit_timeout_s=0.1, backoff_jitter=0.5)
    a = Channel(cfg, seed=7).transmit(1250, 0.0)
    b = Channel(cfg, seed=7).transmit(1250, 0.0)
    assert a == b
    base = 4 * 0.01 + 3 * 0.1
    assert base <= a.device_free_s <= base + 3 * 0.05 + 1e-12


def test_deadline_stops_retries_as_expired():
    """No retry is attempted past deadline_s: the transmit returns a
    failed, expired delivery the moment the next wait cannot land."""
    cfg = ChannelConfig(bandwidth_bps=1e6, drop_prob=1.0, max_attempts=8,
                        retransmit_timeout_s=0.1)
    d = Channel(cfg, seed=0).transmit(1250, t_send=0.0, deadline_s=0.25)
    assert not d.delivered and d.expired
    # attempts at 0.01, 0.12, 0.23; the wait to 0.34 crosses the deadline
    assert d.attempts == 3
    assert d.arrive_s == d.device_free_s == pytest.approx(0.23)


def test_attempt_overrunning_deadline_is_expired_not_delivered():
    """Satellite: a single attempt whose serialization alone overruns
    deadline_s is a deadline miss — the payload would land late, so the
    transmit reports expired, not a clean delivery.  A deadline the
    attempt beats leaves the closed forms bit-exact."""
    cfg = ChannelConfig(bandwidth_bps=1e6, propagation_s=0.0)
    late = Channel(cfg, seed=0).transmit(125000, 0.0, deadline_s=0.5)
    assert not late.delivered and late.expired      # ser = 1.0 s > 0.5 s
    assert late.attempts == 1
    assert late.arrive_s == late.device_free_s == pytest.approx(1.0)
    ok = Channel(cfg, seed=0).transmit(125000, 0.0, deadline_s=1.5)
    assert ok.delivered and not ok.expired
    assert ok.device_free_s == pytest.approx(1.0)


def test_retry_forever_terminates_under_total_loss():
    """Satellite: max_attempts=0 ("app retries forever") + a 100%-loss
    link must terminate as a failed delivery at the safety cap, never
    hang the event loop."""
    cfg = ChannelConfig(bandwidth_bps=1e8, drop_prob=1.0, max_attempts=0,
                        retransmit_timeout_s=1e-4)
    d = Channel(cfg, seed=0).transmit(100, t_send=0.0)
    assert not d.delivered and not d.expired
    assert d.attempts == RETRY_SAFETY_CAP


def test_forced_loss_has_no_final_attempt_rescue():
    """Benign i.i.d. loss delivers on the final attempt (the app keeps
    retrying); a fault-forced loss does not — a dark link delivers
    nothing."""
    cfg = ChannelConfig(bandwidth_bps=1e6, drop_prob=1.0, max_attempts=4,
                        retransmit_timeout_s=0.01)
    assert Channel(cfg, seed=0).transmit(1250, 0.0).delivered
    inj = FaultInjector((Blackout(),), seed=0)
    d = Channel(cfg, seed=0).transmit(1250, 0.0, link=inj.link(0))
    assert not d.delivered and not d.expired and d.attempts == 4


def test_degrade_scales_bandwidth_and_airtime():
    inj = FaultInjector((LinkDegrade(0.0, 10.0, bandwidth_scale=0.5),))
    cfg = ChannelConfig(bandwidth_bps=1e6, propagation_s=0.0)
    d = Channel(cfg, seed=0).transmit(1250, 0.0, link=inj.link(3))
    assert d.delivered and d.attempts == 1
    assert d.airtime_s == pytest.approx(0.02)      # 10 ms doubled
    clean = Channel(cfg, seed=0).transmit(1250, 20.0, link=inj.link(3))
    assert clean.airtime_s == pytest.approx(0.01)  # window over


def test_fault_schedule_replays_deterministically():
    """Same (schedule, seed): identical forced-loss sequences; fault
    randomness is per-client, so interleaving clients doesn't perturb
    either stream."""
    sched = (BurstLoss(0.0, 1.0, p_good_bad=0.3, p_bad_good=0.3),
             LinkDegrade(0.0, 1.0, extra_loss=0.2))
    a = FaultInjector(sched, seed=4)
    b = FaultInjector(sched, seed=4)
    seq_a = [a.link(1).attempt_lost(t) for t in np.linspace(0, 0.9, 50)]
    # interleave a second client's draws into b only
    seq_b = []
    for t in np.linspace(0, 0.9, 50):
        b.link(2).attempt_lost(t)
        seq_b.append(b.link(1).attempt_lost(t))
    assert seq_a == seq_b
    assert any(seq_a) and not all(seq_a)


def test_parse_faults_round_trip():
    sched = parse_faults(
        "blackout:0.05:0.2; burst:0:1:0.2:0.4; degrade:0:1:0.5:0.1;"
        "devstall:0:1:0.03; gwstall:0:1:0.02; corrupt:0:1:0.3")
    kinds = [type(e).__name__ for e in sched]
    assert kinds == ["Blackout", "BurstLoss", "LinkDegrade", "DeviceStall",
                     "GatewayStall", "PayloadCorruption"]
    assert sched[1] == BurstLoss(0.0, 1.0, p_good_bad=0.2, p_bad_good=0.4)
    assert sched[2] == LinkDegrade(0.0, 1.0, bandwidth_scale=0.5,
                                   extra_loss=0.1)
    assert parse_faults("blackout") == (Blackout(),)
    with pytest.raises(ValueError, match="unknown fault kind"):
        parse_faults("meteor:0:1")


# ------------------------------------------------- hardened LZW decode ---

def test_lzw_decode_rejects_corruption_typed():
    """Random truncations and bit flips of valid code streams raise
    `PayloadCorruptionError` (or survive decode into a frame the length
    check catches) — never KeyError/IndexError."""
    rng = np.random.RandomState(0)
    bits, n = 3, 19 * 16
    expect = packed_nbytes(bits, n)
    caught = 0
    for trial in range(60):
        idx = rng.randint(0, 1 << bits, size=n)
        _, codes = compress_payload(pack_indices(idx, bits))
        bad = list(codes)
        if rng.randint(2) and len(bad) > 1:
            bad = bad[:rng.randint(1, len(bad))]
        else:
            i = rng.randint(len(bad))
            bad[i] = int(bad[i]) ^ (1 << rng.randint(14))
        if bad == list(codes):
            continue
        try:
            data = lzw_decode(bad)
        except PayloadCorruptionError:
            caught += 1
            continue
        if len(data) != expect:
            caught += 1                 # framing length check catches it
    assert caught > 10                  # corruption is actually detected


def test_lzw_decode_rejects_bad_head_and_types():
    with pytest.raises(PayloadCorruptionError):
        lzw_decode([300, 0])            # head must be a literal byte
    with pytest.raises(PayloadCorruptionError):
        lzw_decode([-1])
    with pytest.raises(PayloadCorruptionError):
        lzw_decode([5, 99999])          # far past next_code


def test_unpack_indices_rejects_short_frames():
    idx = np.arange(16) % 4
    packed = pack_indices(idx, 2)
    with pytest.raises(PayloadCorruptionError):
        unpack_indices(packed[:-1], 2, 16)
    with pytest.raises(PayloadCorruptionError):
        unpack_indices_batch([packed, packed[:-1]], 2, 16)
    np.testing.assert_array_equal(unpack_indices(packed, 2, 16), idx)


# ------------------------------------------------- gateway degradation ---

def _trace_key(r):
    return [(t.client, t.req, t.t_born, t.t_sent, t.t_arrive, t.t_serve,
             t.t_done, t.e2e_s, t.energy_j, t.attempts, t.status,
             t.deadline_missed) for t in r.traces]


def _run(specs, *, seed=0, width=4, faults=None, gw=None):
    fleet = Fleet(CFG, PARAMS, specs, seed=seed)
    report = OffloadGateway(
        CFG, PARAMS, fleet, gw or GatewayConfig(batch_width=width),
        faults=faults).run()
    return fleet, report


def test_idle_injector_is_bit_identical_to_none():
    """Acceptance: with faults disabled (empty schedule) every trace and
    every logit is bit-identical to a run with no injector at all."""
    specs = mixed_fleet(6, n_requests=3, slo_ms=8.0, deadline_ms=500.0)
    _, plain = _run(specs, seed=5)
    _, idle = _run(specs, seed=5, faults=FaultInjector(()))
    assert _trace_key(plain) == _trace_key(idle)
    assert all(np.array_equal(a.logits, b.logits)
               for a, b in zip(plain.traces, idle.traces))
    assert plain.fallback_rate == idle.fallback_rate == 0.0


def test_total_blackout_all_fallback_bit_identical_local():
    """Acceptance: under a run-long blackout every request completes as a
    Local-NN fallback whose logits equal the standalone local path
    bitwise — including with the retry-forever channel config."""
    forever = dataclasses.replace(WIFI_UDP, max_attempts=0,
                                  retransmit_timeout_s=1e-3)
    specs = (ClientSpec(channel=WIFI_UDP, n_requests=3),
             ClientSpec(channel=forever, n_requests=3))
    fleet, report = _run(specs, faults=FaultInjector((Blackout(),)))
    assert len(report.traces) == 6          # nothing hangs, nothing lost
    assert report.fallback_rate == 1.0
    assert all(t.status == "fallback" for t in report.traces)
    for t in report.traces:
        row = fleet.clients[t.client].row0 + t.req
        np.testing.assert_array_equal(t.logits, fleet.local_logits[row])
        image = jnp.asarray(fleet.images[row:row + 1])
        ref = np.asarray(agile_forward(
            CFG, PARAMS, image, train=False)[1]["local_logits"])[0]
        np.testing.assert_array_equal(t.logits, ref)
        assert t.pred == int(np.argmax(ref))


def test_fault_run_fixed_seed_determinism():
    """Acceptance: a chaos schedule replays identically run-to-run."""
    sched = (Blackout(0.02, 0.1), BurstLoss(0.0, 2.0, p_good_bad=0.3),
             PayloadCorruption(0.0, 2.0, prob=0.5),
             DeviceStall(0.0, 0.5, stall_s=0.01),
             GatewayStall(0.0, 0.5, stall_s=0.01))
    specs = mixed_fleet(8, n_requests=3, deadline_ms=120.0)
    _, r1 = _run(specs, faults=FaultInjector(sched, seed=11))
    _, r2 = _run(specs, faults=FaultInjector(sched, seed=11))
    assert len(r1.traces) == 24
    assert _trace_key(r1) == _trace_key(r2)
    assert all(np.array_equal(a.logits, b.logits)
               for a, b in zip(r1.traces, r2.traces))
    # and a different fault seed actually changes the run
    _, r3 = _run(specs, faults=FaultInjector(sched, seed=12))
    assert _trace_key(r1) != _trace_key(r3)


def test_corruption_degrades_to_erased_floor():
    """Detected corruption serves with every offloaded channel
    zero-filled: logits equal Remote-NN-on-zeros + combine, and no
    exception leaks.  (A bit flip can land on another valid code and
    slip through as a well-framed payload — without checksums that is
    undetectable, and such requests stay 'served'.)"""
    specs = (ClientSpec(channel=WIFI_UDP, n_requests=8),)
    fleet, report = _run(
        specs, faults=FaultInjector((PayloadCorruption(prob=1.0),), seed=2))
    assert len(report.traces) == 8
    assert report.degraded_rate > 0.5
    fh, Cr = fleet.feat_hw, fleet.n_remote
    for t in report.traces:
        if t.status != "degraded":
            continue
        row = fleet.clients[t.client].row0 + t.req
        ref = np.asarray(remote_forward_jit(
            PARAMS, jnp.zeros((1, fh, fh, Cr), jnp.float32),
            jnp.asarray(fleet.local_logits[row:row + 1]),
            temperature=CFG.agile.alpha_temperature))[0]
        np.testing.assert_array_equal(t.logits, ref)


def test_deadline_sheds_and_marks_misses():
    """A stalled gateway + tight deadlines: requests that cannot be
    served in time resolve as shed/fallback at their deadline instant —
    every request still resolves exactly once."""
    sched = (GatewayStall(0.0, 100.0, stall_s=0.25),)
    specs = mixed_fleet(6, n_requests=3, deadline_ms=60.0)
    fleet, report = _run(specs, faults=FaultInjector(sched), width=2)
    assert len(report.traces) == 18
    seen = {(t.client, t.req) for t in report.traces}
    assert len(seen) == 18
    assert report.deadline_miss_rate > 0
    for t in report.traces:
        deadline = t.t_born + 0.060
        if t.status in ("shed", "fallback") and t.deadline_missed:
            assert t.t_done <= deadline + 1e-12
            row = fleet.clients[t.client].row0 + t.req
            np.testing.assert_array_equal(t.logits, fleet.local_logits[row])
        elif t.status == "served":
            assert t.deadline_missed == (t.t_done > deadline)


def test_edf_admission_serves_tightest_deadline_first():
    """While a stalled width-1 pool is busy, a later-arriving narrowband
    request with the tightest deadline jumps the queued WiFi request
    (EDF); without deadlines the same fleet admits in arrival order."""
    def specs(deadlines):
        d0, d1, d2 = deadlines
        return (ClientSpec(channel=WIFI_UDP, n_requests=1,
                           arrival_rate_hz=1e4, deadline_ms=d0),
                ClientSpec(channel=WIFI_UDP, n_requests=1,
                           arrival_rate_hz=1e4, deadline_ms=d1),
                ClientSpec(channel=NARROWBAND, n_requests=1,
                           arrival_rate_hz=1e4, deadline_ms=d2))
    gw = GatewayConfig(batch_width=1)
    stall = FaultInjector((GatewayStall(0.0, 100.0, stall_s=0.1),))
    _, report = _run(specs((5000.0, 5000.0, 300.0)), gw=gw, faults=stall)
    by_client = {t.client: t for t in report.traces}
    assert len(by_client) == 3
    assert all(t.status == "served" for t in report.traces)
    # the narrowband client arrived last, while the first batch held the
    # only slot; both later requests were queued at its completion ...
    first = min(report.traces, key=lambda t: t.t_serve)
    queued = [t for t in report.traces if t is not first]
    assert by_client[2] in queued
    assert by_client[2].t_arrive == max(t.t_arrive for t in report.traces)
    assert all(t.t_arrive < first.t_serve + 0.1 for t in queued)
    # ... and its tighter deadline won the freed slot over the WiFi
    # request queued ahead of it
    other = next(t for t in queued if t is not by_client[2])
    assert by_client[2].t_serve < other.t_serve
    # without deadlines the same contention resolves FIFO
    stall2 = FaultInjector((GatewayStall(0.0, 100.0, stall_s=0.1),))
    _, fifo = _run(specs((None, None, None)), gw=gw, faults=stall2)
    order = sorted(fifo.traces, key=lambda t: t.t_serve)
    arrivals = sorted(fifo.traces, key=lambda t: t.t_arrive)
    assert [t.client for t in order] == [t.client for t in arrivals]


def test_device_and_gateway_stalls_stretch_latency():
    specs = (ClientSpec(channel=WIFI_UDP, n_requests=2),)
    _, base = _run(specs)
    _, stalled = _run(specs, faults=FaultInjector(
        (DeviceStall(0.0, 100.0, stall_s=0.02),
         GatewayStall(0.0, 100.0, stall_s=0.03),)))
    assert len(stalled.traces) == 2
    assert stalled.latency_percentile_ms(50) >= \
        base.latency_percentile_ms(50) + 20.0


# ------------------------------------------------- slot pool churn -------

def test_slot_pool_churn_never_leaks_or_double_assigns():
    """Satellite: randomized acquire/release/preempt/resume churn
    preserves the pool invariants — free() and occupied() partition the
    slots, double acquire and double/foreign release raise SlotError,
    release returns the occupant exactly once, and a preempted rid can
    resume on any free slot (not necessarily the one it vacated)."""
    rng = np.random.RandomState(0)
    pool = SlotPool(6)
    live = {}
    suspended = []
    next_rid = 0
    for _ in range(500):
        choice = rng.randint(4)
        if live and (len(pool.free()) == 0 or choice == 0):
            # plain drain: release without owner check
            slot = int(rng.choice(sorted(live)))
            assert pool.release(slot) == live.pop(slot)
        elif live and choice == 1:
            # preempt: owner-checked release parks the rid off-pool
            slot = int(rng.choice(sorted(live)))
            rid = live.pop(slot)
            assert pool.release(slot, rid) == rid
            suspended.append(rid)
        elif suspended and pool.free() and choice == 2:
            # resume: the suspended rid re-admits on any free slot
            rid = suspended.pop(int(rng.randint(len(suspended))))
            slot = int(rng.choice(pool.free()))
            pool.acquire(slot, rid)
            live[slot] = rid
        elif pool.free():
            slot = int(rng.choice(pool.free()))
            pool.acquire(slot, next_rid)
            live[slot] = next_rid
            next_rid += 1
        free, occ = set(pool.free()), dict(pool.occupied())
        assert free | set(occ) == set(range(6)) and not free & set(occ)
        assert occ == live
        assert len(pool) == 6
    if not pool.free():
        slot0 = sorted(live)[0]
        pool.release(slot0)
        live.pop(slot0)
    slot = pool.free()[0]
    pool.acquire(slot, next_rid)
    with pytest.raises(SlotError, match="already occupied"):
        pool.acquire(slot, next_rid + 1)
    # foreign-owner release is rejected without freeing the slot ...
    with pytest.raises(SlotError, match="owned by"):
        pool.release(slot, next_rid + 1)
    assert pool.rids[slot] == next_rid
    # ... and releasing a free slot twice is a typed error, not a no-op
    assert pool.release(slot, next_rid) == next_rid
    with pytest.raises(SlotError, match="released twice"):
        pool.release(slot)


def test_gateway_pool_returns_to_empty_after_chaos():
    """Fault-driven shed/fallback churn never leaks a gateway feature
    slot: after any chaos run the pool is fully free."""
    sched = (Blackout(0.01, 0.08), PayloadCorruption(prob=0.4),
             GatewayStall(0.0, 0.2, stall_s=0.05))
    specs = mixed_fleet(6, n_requests=4, deadline_ms=80.0)
    fleet = Fleet(CFG, PARAMS, specs, seed=1)
    gw = OffloadGateway(CFG, PARAMS, fleet, GatewayConfig(batch_width=3),
                        faults=FaultInjector(sched, seed=5))
    report = gw.run()
    assert len(report.traces) == 24
    assert gw._slots.free() == list(range(3))
    assert not gw._slots.any_occupied()
