"""Data pipeline (prefetch, host slicing) + generic training loop."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import HostDataLoader, host_slice, token_batch_fn
from repro.data.synthetic import SyntheticTokens, TokenDatasetSpec
from repro.train.loop import LoopConfig, TrainState, run_training


def test_loader_prefetch_order_and_determinism():
    data = SyntheticTokens(TokenDatasetSpec(vocab=16, seq_len=8))
    fn = token_batch_fn(data, 4)
    loader = HostDataLoader(fn, prefetch=2)
    b0 = next(loader)
    b1 = next(loader)
    loader.close()
    assert b0["tokens"].shape == (4, 7)
    np.testing.assert_array_equal(b0["tokens"], fn(0)["tokens"])
    np.testing.assert_array_equal(b1["tokens"], fn(1)["tokens"])


def test_loader_propagates_errors():
    def bad(step):
        raise ValueError("boom")
    loader = HostDataLoader(bad)
    try:
        next(loader)
        raise AssertionError("expected ValueError")
    except ValueError:
        pass
    finally:
        loader.close()


def test_host_slice():
    batch = {"x": np.arange(8).reshape(8, 1)}
    s = host_slice(batch, host_id=1, n_hosts=4)
    np.testing.assert_array_equal(s["x"][:, 0], [2, 3])


def test_run_training_converges_quadratic():
    params = {"w": jnp.asarray(4.0)}
    opt = {"m": jnp.zeros(())}

    @jax.jit
    def step_fn(p, o, batch):
        g = 2 * p["w"]
        m = 0.9 * o["m"] + g
        return {"w": p["w"] - 0.05 * m}, {"m": m}, {"loss": p["w"] ** 2}

    def batches():
        while True:
            yield {}

    state = run_training(TrainState(params, opt), step_fn, batches(),
                         loop=LoopConfig(total_steps=120, log_every=40))
    assert abs(float(state.params["w"])) < 1e-2
    assert state.history[-1]["loss"] < state.history[0]["loss"]
