"""XAI attribution tools: IG axioms and saliency sanity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.xai import (
    channel_importance,
    evaluate_importance,
    gradient_saliency,
    integrated_gradients,
)

KEY = jax.random.PRNGKey(3)


def _linear_predict(W):
    def predict(feats):  # feats (B, C) -> logits (B, n)
        return feats @ W
    return predict


def test_ig_completeness_axiom():
    """For F(x) = sum over the path, sum_i IG_i == F(x) - F(0) for the
    target score (up to interpolation error).  Use a linear model where IG
    is exact with one step."""
    C, n = 6, 3
    W = jax.random.normal(KEY, (C, n))
    feats = jax.random.normal(KEY, (4, C))
    targets = jnp.zeros((4,), jnp.int32)
    predict = _linear_predict(W)

    # score is softmax prob — nonlinear, so use many steps and check the
    # completeness residual is small
    attr = integrated_gradients(predict, feats, targets, steps=256)
    # signed completeness: recompute without abs via raw path integral
    def score(f):
        p = jax.nn.softmax(predict(f), axis=-1)
        return p[jnp.arange(4), targets]

    total = score(feats) - score(jnp.zeros_like(feats))
    # attr is |delta * grads|; reconstruct signed sum
    signed = jnp.sum(feats * jax.grad(lambda f: jnp.sum(score(f)))(feats), -1)
    # weak check: attribution mass correlates with |F(x)-F(0)|
    assert attr.shape == feats.shape
    assert jnp.all(attr >= 0)


def test_ig_zero_baseline_zero_input():
    W = jax.random.normal(KEY, (4, 2))
    predict = _linear_predict(W)
    feats = jnp.zeros((2, 4))
    attr = integrated_gradients(predict, feats, jnp.zeros((2,), jnp.int32), steps=8)
    np.testing.assert_allclose(attr, 0.0, atol=1e-7)


def test_saliency_identifies_dominant_channel():
    """A channel with 10x the weight should get the highest importance."""
    C = 5
    W = jnp.ones((C, 2)) * 0.1
    W = W.at[2, 0].set(10.0)
    predict = _linear_predict(W)
    feats = jnp.abs(jax.random.normal(KEY, (8, C))) + 0.5
    imp = evaluate_importance(predict, feats, jnp.zeros((8,), jnp.int32),
                              method="saliency")
    assert imp.shape == (8, C)
    np.testing.assert_allclose(jnp.sum(imp, -1), 1.0, rtol=1e-5)
    assert int(jnp.argmax(jnp.mean(imp, 0))) == 2


def test_ig_identifies_dominant_channel():
    C = 5
    W = jnp.ones((C, 2)) * 0.1
    W = W.at[3, 0].set(10.0)
    predict = _linear_predict(W)
    feats = jnp.abs(jax.random.normal(KEY, (8, C))) + 0.5
    imp = evaluate_importance(predict, feats, jnp.zeros((8,), jnp.int32),
                              method="ig", steps=32)
    assert int(jnp.argmax(jnp.mean(imp, 0))) == 3


def test_channel_importance_aggregates_spatial():
    attr = jnp.ones((2, 4, 4, 3))
    attr = attr.at[..., 1].set(3.0)
    imp = channel_importance(attr)
    assert imp.shape == (2, 3)
    np.testing.assert_allclose(jnp.sum(imp, -1), 1.0, rtol=1e-6)
    assert float(imp[0, 1]) > float(imp[0, 0])
