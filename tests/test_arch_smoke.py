"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned architecture runs one forward/train step on CPU with correct
output shapes and no NaNs; decode matches prefill logits."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import backbone as bb

KEY = jax.random.PRNGKey(7)


def _batch_for(cfg, B, T):
    batch = {"tokens": jax.random.randint(KEY, (B, T), 0, cfg.vocab),
             "labels": jax.random.randint(KEY, (B, T), 0, cfg.vocab)}
    if cfg.vlm is not None:
        batch["patches"] = jax.random.normal(
            KEY, (B, cfg.vlm.n_patches, cfg.vlm.vision_dim))
    if cfg.encdec is not None:
        batch["frames"] = jax.random.normal(
            KEY, (B, cfg.encdec.n_frames, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.d_model <= 512 and cfg.vocab <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    params = bb.init_params(cfg, KEY)
    batch = _batch_for(cfg, B=2, T=16)
    loss, metrics = bb.forward_loss(cfg, params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), (arch, loss)

    # one SGD step reduces nothing catastrophic (finite grads)
    def loss_fn(p):
        return bb.forward_loss(cfg, p, batch)[0]

    grads = jax.grad(loss_fn)(params)
    gnorm = sum(float(jnp.sum(jnp.abs(g)))
                for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0.0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_decode_consistency(arch):
    cfg = get_config(arch).reduced()
    params = bb.init_params(cfg, KEY)
    B, T = 2, 10
    tokens = jax.random.randint(KEY, (B, T + 2), 0, cfg.vocab)
    batch = _batch_for(cfg, B, T)
    del batch["labels"]
    batch["tokens"] = tokens[:, :T]
    logits0, cache, total_T = bb.prefill(cfg, params, batch)
    assert logits0.shape == (B, cfg.vocab)
    cl = total_T
    for step in range(2):
        logits, cache = bb.decode_step(cfg, params,
                                       tokens[:, T + step:T + step + 1],
                                       cache, cl)
        cl += 1
        b2 = dict(batch)
        b2["tokens"] = tokens[:, :T + step + 1]
        ref, _, _ = bb.prefill(cfg, params, b2)
        np.testing.assert_allclose(logits, ref, atol=3e-3, rtol=3e-3)


def test_all_ten_archs_registered():
    from repro.configs import list_configs
    assert set(ASSIGNED_ARCHS) <= set(list_configs())
    assert len(ASSIGNED_ARCHS) == 10


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_full_config_matches_assignment(arch):
    spec = {
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
    }[arch]
    cfg = get_config(arch)
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab) == spec
    moe_spec = {
        "moonshot-v1-16b-a3b": (64, 6),
        "jamba-1.5-large-398b": (16, 2),
        "arctic-480b": (128, 2),
        "mixtral-8x7b": (8, 2),
    }
    if arch in moe_spec:
        assert (cfg.moe.n_experts, cfg.moe.top_k) == moe_spec[arch]
    else:
        assert cfg.moe is None
