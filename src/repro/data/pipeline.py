"""Host-side data pipeline: batching, device placement, background
prefetch.

On a pod each host feeds its addressable shard of the global batch; on
this container the pipeline degenerates to single-host but keeps the same
interface (global_batch -> per-host slice -> device_put with the batch
sharding).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax
import numpy as np


class HostDataLoader:
    """Wraps a `batch_fn(step) -> pytree of np arrays` with background
    prefetch and optional sharded device placement."""

    def __init__(self, batch_fn: Callable[[int], dict], *,
                 prefetch: int = 2, sharding=None, start_step: int = 0):
        self.batch_fn = batch_fn
        self.sharding = sharding
        self._q: queue.Queue = queue.Queue(maxsize=max(1, prefetch))
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            try:
                batch = self.batch_fn(step)
            except Exception as e:  # propagate to consumer
                self._q.put(e)
                return
            self._q.put(batch)
            step += 1

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        item = self._q.get()
        if isinstance(item, Exception):
            raise item
        if self.sharding is not None:
            item = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), item, self.sharding)
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


def host_slice(global_batch: dict, *, host_id: int = 0,
               n_hosts: int = 1) -> dict:
    """Slice a host's portion of the global batch (process-sharded input
    pipelines on multi-host pods)."""
    def sl(x):
        per = x.shape[0] // n_hosts
        return x[host_id * per:(host_id + 1) * per]
    return jax.tree_util.tree_map(sl, global_batch)


def token_batch_fn(data, batch_size: int, *, seed_base: int = 0):
    """Adapter for SyntheticTokens: step -> {tokens, labels}."""
    def fn(step: int) -> dict:
        toks = data.batch(batch_size, seed=seed_base + step)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}
    return fn


def image_batch_fn(data, batch_size: int, *, seed_base: int = 0):
    """Adapter for SyntheticImages: step -> {images, labels}."""
    def fn(step: int) -> dict:
        images, labels = data.batch(batch_size, seed=seed_base + step)
        return {"images": images, "labels": labels}
    return fn
