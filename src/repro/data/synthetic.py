"""Synthetic datasets (offline container -> procedurally generated data).

Images: class-conditional structured images (per-class smooth random
template + localized pattern + sample noise).  Difficulty is controlled
by the noise scale: a small CNN reaches high accuracy in a few hundred
steps, which keeps the paper-claim validations meaningful on CPU.

Tokens: a mixture of per-sequence Markov chains, so next-token loss has
learnable structure for the LM architectures' end-to-end driver.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ImageDatasetSpec:
    n_classes: int = 10
    image_size: int = 32
    noise: float = 0.35
    seed: int = 0


class SyntheticImages:
    """Deterministic, infinite class-conditional image sampler."""

    def __init__(self, spec: ImageDatasetSpec):
        self.spec = spec
        rng = np.random.RandomState(spec.seed)
        s, c = spec.image_size, spec.n_classes
        # smooth low-frequency per-class templates
        low = rng.randn(c, 8, 8, 3).astype(np.float32)
        self.templates = np.stack([
            _upsample(low[i], s) for i in range(c)], axis=0)
        # localized high-frequency signature per class
        self.freqs = rng.uniform(1.0, 4.0, size=(c, 2)).astype(np.float32)
        xx, yy = np.meshgrid(np.linspace(0, np.pi * 2, s),
                             np.linspace(0, np.pi * 2, s))
        self.xx, self.yy = xx.astype(np.float32), yy.astype(np.float32)

    def batch(self, batch_size: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
        rng = np.random.RandomState(seed)
        spec = self.spec
        labels = rng.randint(0, spec.n_classes, size=batch_size)
        imgs = self.templates[labels].copy()
        for i, y in enumerate(labels):
            fx, fy = self.freqs[y]
            wave = 0.5 * np.sin(fx * self.xx + fy * self.yy)
            imgs[i] += wave[..., None]
        imgs += spec.noise * rng.randn(*imgs.shape).astype(np.float32)
        return imgs.astype(np.float32), labels.astype(np.int32)

    def epoch(self, n_batches: int, batch_size: int, *, base_seed: int = 0):
        for i in range(n_batches):
            yield self.batch(batch_size, base_seed * 10_000 + i)


def _upsample(img: np.ndarray, size: int) -> np.ndarray:
    """Nearest+smooth upsample of (h, w, c) to (size, size, c)."""
    h = img.shape[0]
    rep = size // h
    up = np.repeat(np.repeat(img, rep, axis=0), rep, axis=1)
    # light box blur for smoothness
    k = rep
    pad = np.pad(up, ((k, k), (k, k), (0, 0)), mode="edge")
    out = np.zeros_like(up)
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            out += pad[k + dy:k + dy + size, k + dx:k + dx + size]
    return (out / 9.0).astype(np.float32)


@dataclasses.dataclass
class TokenDatasetSpec:
    vocab: int = 512
    seq_len: int = 128
    n_modes: int = 8
    seed: int = 0


class SyntheticTokens:
    """Mixture-of-Markov-chains language data."""

    def __init__(self, spec: TokenDatasetSpec):
        self.spec = spec
        rng = np.random.RandomState(spec.seed)
        # sparse-ish transition matrices per mode
        trans = rng.dirichlet(np.ones(spec.vocab) * 0.05,
                              size=(spec.n_modes, spec.vocab))
        self.trans = trans.astype(np.float64)

    def batch(self, batch_size: int, seed: int) -> np.ndarray:
        rng = np.random.RandomState(seed)
        spec = self.spec
        out = np.zeros((batch_size, spec.seq_len), np.int32)
        modes = rng.randint(0, spec.n_modes, size=batch_size)
        state = rng.randint(0, spec.vocab, size=batch_size)
        out[:, 0] = state
        for t in range(1, spec.seq_len):
            for b in range(batch_size):
                p = self.trans[modes[b], state[b]]
                state[b] = rng.choice(spec.vocab, p=p)
            out[:, t] = state
        return out
