"""LZW codec (paper §6 uses standard LZW [49] after quantization).

Operates on byte sequences; used by the offload runtime to measure the
actual transmitted payload size (Table 2 / Figure 21(c) reproductions).
Pure Python — it runs on the host side of the serving engine, not inside
jit.
"""
from __future__ import annotations

import numpy as np


def lzw_encode(data: bytes) -> list[int]:
    """Classic LZW: returns a list of integer codes."""
    if not data:
        return []
    table = {bytes([i]): i for i in range(256)}
    next_code = 256
    out = []
    w = bytes([data[0]])
    for b in data[1:]:
        wb = w + bytes([b])
        if wb in table:
            w = wb
        else:
            out.append(table[w])
            table[wb] = next_code
            next_code += 1
            w = bytes([b])
    out.append(table[w])
    return out


def lzw_decode(codes: list[int]) -> bytes:
    if not codes:
        return b""
    table = {i: bytes([i]) for i in range(256)}
    next_code = 256
    w = table[codes[0]]
    out = [w]
    for c in codes[1:]:
        if c in table:
            entry = table[c]
        elif c == next_code:
            entry = w + w[:1]
        else:
            raise ValueError(f"bad LZW code {c}")
        out.append(entry)
        table[next_code] = w + entry[:1]
        next_code += 1
        w = entry
    return b"".join(out)


def lzw_encoded_bytes(codes: list[int]) -> int:
    """Size of the code stream with variable-width packing (as the MCU
    implementation does): code i is emitted at the bit width needed for
    the table size at that moment."""
    if not codes:
        return 0
    bits = 0
    table_size = 256
    width = 9
    for _ in codes:
        bits += width
        table_size += 1
        if table_size >= (1 << width):
            width += 1
    return (bits + 7) // 8


def compress_payload(data: bytes) -> tuple[int, list[int]]:
    """Returns (compressed_byte_count, codes)."""
    codes = lzw_encode(data)
    return lzw_encoded_bytes(codes), codes


def pack_indices(idx: np.ndarray, bits: int) -> bytes:
    """Bit-pack quantization indices (B*H*W*C elements, `bits` bits each)."""
    idx = np.asarray(idx, dtype=np.uint8).ravel()
    if bits == 8:
        return idx.tobytes()
    bitstream = np.unpackbits(idx[:, None], axis=1, count=8)[:, 8 - bits:]
    return np.packbits(bitstream.ravel()).tobytes()
