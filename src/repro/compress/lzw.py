"""LZW codec (paper §6 uses standard LZW [49] after quantization).

Operates on byte sequences; used by the offload runtime to measure the
actual transmitted payload size (Table 2 / Figure 21(c) reproductions).
Pure Python — it runs on the host side of the serving engine, not inside
jit.  The encoder keys its dictionary on packed (prefix_code, byte) ints
rather than concatenated byte strings, so each input byte is O(1) dict
work with no string allocation; the variable-width stream size is a
closed form of the code count.
"""
from __future__ import annotations

import numpy as np


class PayloadCorruptionError(ValueError):
    """A payload failed to decode: truncated or bit-flipped on the air.

    Raised (instead of an uncaught KeyError/IndexError or silently wrong
    data) by `lzw_decode` on an impossible code and by `unpack_indices`
    on a payload too short for its framing.  The gateway treats it as a
    droppable fault — the request degrades to zero-filled channels or a
    Local-NN fallback instead of crashing the event loop."""


def lzw_encode(data: bytes) -> list[int]:
    """Classic LZW: returns a list of integer codes.

    The table maps (prefix_code << 8) | next_byte -> code; single bytes
    are implicitly codes 0..255.  Emitted codes are identical to the
    textbook string-keyed formulation.
    """
    if not data:
        return []
    table: dict[int, int] = {}
    next_code = 256
    out: list[int] = []
    w = data[0]
    for b in data[1:]:
        key = (w << 8) | b
        nxt = table.get(key)
        if nxt is not None:
            w = nxt
        else:
            out.append(w)
            table[key] = next_code
            next_code += 1
            w = b
    out.append(w)
    return out


# decoder codebook template: built once, copied per call — the 256
# single-byte entries never change, only the learned suffix does
_DECODE_BASE = {i: bytes([i]) for i in range(256)}


def lzw_decode(codes: list[int]) -> bytes:
    if not codes:
        return b""
    table = dict(_DECODE_BASE)
    next_code = 256
    if not isinstance(codes[0], int) or not 0 <= codes[0] < 256:
        raise PayloadCorruptionError(
            f"bad LZW stream head {codes[0]!r}: the first code must be a "
            "literal byte")
    w = table[codes[0]]
    out = [w]
    for c in codes[1:]:
        if not isinstance(c, int) or c < 0:
            raise PayloadCorruptionError(f"bad LZW code {c!r}")
        if c in table:
            entry = table[c]
        elif c == next_code:
            entry = w + w[:1]
        else:
            raise PayloadCorruptionError(
                f"bad LZW code {c} (table holds {next_code})")
        out.append(entry)
        table[next_code] = w + entry[:1]
        next_code += 1
        w = entry
    return b"".join(out)


def lzw_encoded_bytes(codes: list[int]) -> int:
    """Size of the code stream with variable-width packing (as the MCU
    implementation does): code i is emitted at the bit width needed for
    the table size at that moment — i.e. bit_length(256 + i), never below
    9.  Computed per contiguous width segment instead of per code."""
    n = len(codes)
    if n == 0:
        return 0
    bits = 0
    width = 9
    i = 0
    while i < n:
        hi = min(n, (1 << width) - 256)   # codes still emitted at `width`
        bits += (hi - i) * width
        i = hi
        width += 1
    return (bits + 7) // 8


def compress_payload(data: bytes) -> tuple[int, list[int]]:
    """Returns (compressed_byte_count, codes)."""
    codes = lzw_encode(data)
    return lzw_encoded_bytes(codes), codes


def pack_indices(idx: np.ndarray, bits: int) -> bytes:
    """Bit-pack quantization indices (H*W*C elements, `bits` bits each)."""
    idx = np.asarray(idx, dtype=np.uint8).ravel()
    if bits == 8:
        return idx.tobytes()
    bitstream = np.unpackbits(idx[:, None], axis=1, count=8)[:, 8 - bits:]
    return np.packbits(bitstream.ravel()).tobytes()


def packed_nbytes(bits: int, count: int) -> int:
    """Byte length of a well-framed ``pack_indices`` payload: `count`
    indices at `bits` bits, padded to a byte boundary."""
    return count if bits == 8 else (count * bits + 7) // 8


def unpack_indices(data: bytes, bits: int, count: int) -> np.ndarray:
    """Inverse of ``pack_indices``: the first `count` indices of a packed
    payload (trailing pad bits from the byte-boundary framing are
    discarded).  A payload shorter than its framing demands raises
    `PayloadCorruptionError` instead of returning a ragged array."""
    if len(data) < packed_nbytes(bits, count):
        raise PayloadCorruptionError(
            f"truncated payload: {len(data)} bytes cannot hold {count} "
            f"indices at {bits} bits")
    buf = np.frombuffer(data, np.uint8)
    if bits == 8:
        return buf[:count].astype(np.int32)
    bitstream = np.unpackbits(buf)[:count * bits].reshape(count, bits)
    weights = (1 << np.arange(bits - 1, -1, -1)).astype(np.int32)
    return bitstream.astype(np.int32) @ weights


def unpack_indices_batch(payloads: list[bytes], bits: int,
                         count: int) -> np.ndarray:
    """Decode a batch of equal-framing payloads in one vectorized pass.

    Every payload packs exactly `count` indices at `bits` bits (the
    gateway groups arrivals by framing before decoding).  Returns a
    (B, count) int32 array, row-identical to per-payload
    ``unpack_indices``."""
    need = packed_nbytes(bits, count)
    if any(len(p) != len(payloads[0]) or len(p) < need for p in payloads):
        raise PayloadCorruptionError(
            f"ragged or truncated payload batch: need {need} bytes per row "
            f"for {count} indices at {bits} bits")
    buf = np.frombuffer(b"".join(payloads), np.uint8)
    buf = buf.reshape(len(payloads), -1)
    if bits == 8:
        return buf[:, :count].astype(np.int32)
    bitstream = np.unpackbits(buf, axis=1)[:, :count * bits]
    bitstream = bitstream.reshape(len(payloads), count, bits)
    weights = (1 << np.arange(bits - 1, -1, -1)).astype(np.int32)
    return bitstream.astype(np.int32) @ weights


def pack_indices_batch(idx: np.ndarray, bits: int) -> list[bytes]:
    """Bit-pack a whole batch in one vectorized pass.

    idx: (B, ...) index array.  Returns one bytes object per sample,
    byte-identical to ``pack_indices(idx[b], bits)`` (each sample is
    padded to its own byte boundary, matching the per-sample radio
    framing)."""
    idx = np.asarray(idx, dtype=np.uint8).reshape(idx.shape[0], -1)
    if bits == 8:
        return [row.tobytes() for row in idx]
    # MSB-first bit expansion by shifts: skips the 8-wide unpackbits
    # intermediate and its non-contiguous slice
    shifts = np.arange(bits - 1, -1, -1, dtype=np.uint8)
    bitstream = (idx[..., None] >> shifts) & 1
    packed = np.packbits(bitstream.reshape(idx.shape[0], -1), axis=1)
    return [row.tobytes() for row in packed]
