"""Learning-based quantization of offloaded features (paper §6, [4]).

Soft-to-hard vector quantization (Agustsson et al. 2017), scalar variant:
a trainable codebook of L centers; training uses a softmax-weighted soft
assignment (differentiable), inference uses hard nearest-center indices
(straight-through estimator bridges the two).  The hard indices are what
the runtime LZW-compresses and puts on the radio.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantizer_init(n_centers: int = 8, lo: float = -4.0, hi: float = 4.0):
    """Codebook initialized to a uniform grid (learns during training)."""
    return {"centers": jnp.linspace(lo, hi, n_centers).astype(jnp.float32)}


def soft_quantize(params, x, *, temperature: float = 1.0):
    """Differentiable soft assignment: sum_l softmax(-d^2/T) * c_l."""
    d2 = (x[..., None] - params["centers"]) ** 2
    w = jax.nn.softmax(-d2 / temperature, axis=-1)
    return jnp.sum(w * params["centers"], axis=-1)


def hard_indices(params, x) -> jnp.ndarray:
    """Nearest-center index per element (what gets transmitted)."""
    d2 = (x[..., None] - params["centers"]) ** 2
    return jnp.argmin(d2, axis=-1).astype(jnp.int32)


def dequantize(params, idx) -> jnp.ndarray:
    return jnp.take(params["centers"], idx)


def quantize_ste(params, x, *, temperature: float = 1.0):
    """Train-time op: hard values forward, soft gradient backward."""
    soft = soft_quantize(params, x, temperature=temperature)
    hard = dequantize(params, hard_indices(params, x))
    return soft + jax.lax.stop_gradient(hard - soft)


def quantization_bits(n_centers: int) -> int:
    return max(1, (n_centers - 1).bit_length())
