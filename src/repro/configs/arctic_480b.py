"""arctic-480b [moe] — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base]."""
from repro.configs.base import ArchConfig, MoESpec, register

CONFIG = register(ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab=32000,
    moe=MoESpec(n_experts=128, top_k=2, expert_d_ff=4864,
                dense_residual_ff=7168 * 2),  # dense-MoE hybrid residual path
    param_dtype="bfloat16",
    source="hf:Snowflake/snowflake-arctic-base",
))
