"""internvl2-1b [vlm] — InternViT (STUBBED frontend) + InternLM2 LM backbone
[arXiv:2404.16821]."""
from repro.configs.base import ArchConfig, VLMSpec, register

CONFIG = register(ArchConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151655,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    vlm=VLMSpec(n_patches=256, vision_dim=1024),
    source="arXiv:2404.16821",
))
