"""Architecture + run configuration dataclasses and the config registry."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

import jax.numpy as jnp


@dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    expert_d_ff: int
    every: int = 1                 # MoE FFN every `every`-th layer (jamba: 2)
    dense_residual_ff: int = 0     # arctic: parallel dense FFN width
    shared_expert_ff: int = 0      # moonshot: always-on shared expert width
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class HybridSpec:
    """Jamba-style interleave: one attention layer per `period` layers."""
    period: int = 8                # 1:7 attention:mamba
    attn_index: int = 0            # position of the attention layer in the block
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclass(frozen=True)
class XLSTMSpec:
    period: int = 8                # one sLSTM per 8 layers, rest mLSTM
    slstm_index: int = 7


@dataclass(frozen=True)
class EncDecSpec:
    n_encoder_layers: int = 4
    n_frames: int = 1500           # whisper-tiny 30s mel frames / 2 (conv stride)


@dataclass(frozen=True)
class VLMSpec:
    n_patches: int = 256           # stubbed ViT patch embeddings per image
    vision_dim: int = 1024         # raw frontend width before projector


@dataclass(frozen=True)
class AgileSpec:
    """AgileNN split-serving integration (the paper's technique)."""
    enabled: bool = False
    extractor_channels: int = 24   # lightweight on-device feature extractor
    k: int = 5                     # channels retained locally (top importance)
    rho: float = 0.8               # required cumulative normalized importance
    lam: float = 0.3               # loss mixing lambda
    alpha_temperature: float = 6.0 # T in alpha = sigmoid(w/T)
    ig_steps: int = 16             # integrated-gradients interpolations


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 => d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    tie_embeddings: bool = False
    sliding_window: int = 0        # native SWA (mixtral: 4096)
    long_context_window: int = 8192  # SWA used for long_500k on full-attn archs
    moe: Optional[MoESpec] = None
    hybrid: Optional[HybridSpec] = None
    xlstm: Optional[XLSTMSpec] = None
    encdec: Optional[EncDecSpec] = None
    vlm: Optional[VLMSpec] = None
    agile: AgileSpec = field(default_factory=AgileSpec)
    param_dtype: str = "float32"   # big archs: bfloat16
    source: str = ""               # citation

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def superblock(self) -> int:
        """Layers per scanned superblock."""
        if self.hybrid is not None:
            return self.hybrid.period
        if self.xlstm is not None:
            return self.xlstm.period
        return 1

    @property
    def n_superblocks(self) -> int:
        assert self.n_layers % self.superblock == 0, (self.name, self.n_layers, self.superblock)
        return self.n_layers // self.superblock

    @property
    def dtype(self):
        return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[self.param_dtype]

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: <=2 superblocks, d_model <= 512, <= 4 experts."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv_heads = min(self.n_kv_heads, max(1, n_heads // 2))
        while n_heads % n_kv_heads:
            n_kv_heads -= 1
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, n_experts=min(4, self.moe.n_experts),
                top_k=min(2, self.moe.top_k),
                expert_d_ff=min(128, self.moe.expert_d_ff),
                dense_residual_ff=min(128, self.moe.dense_residual_ff),
                shared_expert_ff=min(128, self.moe.shared_expert_ff))
        encdec = None
        if self.encdec is not None:
            encdec = dataclasses.replace(self.encdec, n_encoder_layers=2, n_frames=16)
        vlm = None
        if self.vlm is not None:
            vlm = dataclasses.replace(self.vlm, n_patches=8, vision_dim=64)
        # hybrid/xlstm superblocks already contain several sublayers; one
        # superblock keeps CPU smoke tests fast while covering every sublayer kind
        max_sb = 1 if self.superblock > 1 else 2
        return dataclasses.replace(
            self, name=self.name + "-reduced",
            n_layers=self.superblock * min(max_sb, self.n_superblocks),
            d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv_heads,
            head_dim=0, d_ff=min(self.d_ff, 512), vocab=min(self.vocab, 512),
            moe=moe, encdec=encdec, vlm=vlm, param_dtype="float32")


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    # import side-effect registration
    import repro.configs  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)
