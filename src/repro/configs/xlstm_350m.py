"""xlstm-350m [ssm] — sLSTM + mLSTM blocks (7:1 mLSTM:sLSTM) [arXiv:2405.04517]."""
from repro.configs.base import ArchConfig, XLSTMSpec, register

CONFIG = register(ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                 # xLSTM blocks carry their own projections
    vocab=50304,
    xlstm=XLSTMSpec(period=8, slstm_index=7),
    source="arXiv:2405.04517",
))
