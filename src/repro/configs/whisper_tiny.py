"""whisper-tiny [audio] — encoder-decoder with a conv mel frontend (STUBBED:
input_specs provides precomputed frame embeddings) [arXiv:2212.04356]."""
from repro.configs.base import ArchConfig, EncDecSpec, register

CONFIG = register(ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,             # decoder layers; encoder in EncDecSpec
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    norm="layernorm",
    rope_theta=0.0,         # whisper uses learned/sinusoidal positions
    encdec=EncDecSpec(n_encoder_layers=4, n_frames=1500),
    source="arXiv:2212.04356",
))
