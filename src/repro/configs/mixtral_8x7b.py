"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention [arXiv:2401.04088]."""
from repro.configs.base import ArchConfig, MoESpec, register

CONFIG = register(ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    moe=MoESpec(n_experts=8, top_k=2, expert_d_ff=14336),
    param_dtype="bfloat16",
    source="arXiv:2401.04088",
))
