"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887]."""
from repro.configs.base import ArchConfig, HybridSpec, MoESpec, register

CONFIG = register(ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    hybrid=HybridSpec(period=8, attn_index=0, d_state=16, d_conv=4, expand=2),
    moe=MoESpec(n_experts=16, top_k=2, expert_d_ff=24576, every=2),
    param_dtype="bfloat16",
    source="arXiv:2403.19887",
))
