"""Config registry: importing this package registers all architectures."""
from repro.configs import (  # noqa: F401
    arctic_480b,
    internvl2_1b,
    jamba_1_5_large_398b,
    llama3_2_1b,
    mixtral_8x7b,
    moonshot_v1_16b_a3b,
    qwen2_0_5b,
    qwen2_1_5b,
    whisper_tiny,
    xlstm_350m,
)
from repro.configs.agilenn_cifar import AgileNNConfig  # noqa: F401
from repro.configs.base import (  # noqa: F401
    ArchConfig,
    InputShape,
    get_config,
    list_configs,
)
from repro.configs.shapes import SHAPES, get_shape  # noqa: F401

ASSIGNED_ARCHS = [
    "internvl2-1b",
    "moonshot-v1-16b-a3b",
    "qwen2-1.5b",
    "xlstm-350m",
    "jamba-1.5-large-398b",
    "arctic-480b",
    "qwen2-0.5b",
    "llama3.2-1b",
    "whisper-tiny",
    "mixtral-8x7b",
]
