"""The paper's own evaluation configuration: AgileNN on CIFAR-scale images.

Feature extractor: 2 conv layers x 24 channels; Local NN: GAP + dense;
Remote NN: MobileNetV2-style (first conv removed, consumes extractor
features); Reference NN: a larger pre-trained CNN (EfficientNet role).
(Paper §7: images scaled to 96x96.)
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.configs.base import AgileSpec


@dataclass(frozen=True)
class AgileNNConfig:
    name: str = "agilenn-cifar"
    image_size: int = 32           # synthetic CIFAR-like (96 in the paper; 32 keeps CPU tests fast)
    n_classes: int = 10
    extractor_channels: int = 24   # paper: 2 conv layers, 24 output channels each
    extractor_layers: int = 2
    local_hidden: int = 0          # Local NN = GAP + dense (minimum complexity)
    remote_width: int = 64         # MobileNetV2-ish width multiplier base
    remote_blocks: int = 6
    reference_width: int = 96      # larger reference CNN (pre-trained)
    reference_blocks: int = 8
    agile: AgileSpec = field(default_factory=lambda: AgileSpec(
        enabled=True, extractor_channels=24, k=5, rho=0.8, lam=0.3,
        alpha_temperature=6.0, ig_steps=16))
    # device model (paper's implementation, §6-7)
    mcu_hz: float = 216e6          # STM32F746 Cortex-M7
    link_bps: float = 6e6          # ESP-WROOM WiFi, UDP 6 Mbps
    mcu_macs_per_cycle: float = 1.0  # CMSIS-NN int8 MAC throughput (approx)


def gateway_demo_config() -> AgileNNConfig:
    """The CPU-sized AgileNN system shared by every offload-gateway demo
    surface (launch --gateway, benchmarks/gateway.py,
    examples/gateway_demo.py) — one definition so the CLI, the example
    and the benchmark baseline cannot silently diverge."""
    return AgileNNConfig(image_size=16, remote_width=16, remote_blocks=2,
                         reference_width=16, reference_blocks=2,
                         agile=AgileSpec(enabled=True, extractor_channels=24,
                                         k=5, rho=0.8, lam=0.3, ig_steps=2))
