"""moonshot-v1-16b-a3b [dense->moe] — Moonlight 16B-A3B: MoE 64e top-6 with a
shared expert [hf:moonshotai/Moonlight-16B-A3B]."""
from repro.configs.base import ArchConfig, MoESpec, register

CONFIG = register(ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=163840,
    rope_theta=50_000.0,
    moe=MoESpec(n_experts=64, top_k=6, expert_d_ff=1408,
                shared_expert_ff=2816),  # 2 shared experts' worth
    param_dtype="bfloat16",
    source="hf:moonshotai/Moonlight-16B-A3B",
))
