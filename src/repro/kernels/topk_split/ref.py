"""Pure-jnp oracle for the channel-permute/split kernel."""
from __future__ import annotations

import jax.numpy as jnp


def channel_permute_ref(x, perm):
    return jnp.take(x, jnp.asarray(perm), axis=-1)


def split_ref(x, perm, k: int):
    y = channel_permute_ref(x, perm)
    return y[..., :k], y[..., k:]
