"""Pallas TPU kernel: AgileNN channel split via static permutation.

The deployed split is a channel gather: out[..., c] = in[..., perm[c]],
then a slice into (local k, remote C-k).  Because the permutation is
static (fixed at training time — that is the point of the disorder loss),
it compiles to a constant-index gather over the lane dimension; the
kernel processes (rows, C) tiles and emits the permuted tile, so split
costs one VMEM pass and zero compute.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _permute_kernel(x_ref, o_ref, *, perm: tuple):
    x = x_ref[...]                                       # (rows, C)
    cols = [x[:, p:p + 1] for p in perm]                 # static gather
    o_ref[...] = jnp.concatenate(cols, axis=1)


def channel_permute_tpu(x, perm, *, block_rows: int = 256,
                        interpret: bool = False):
    """x: (N, C); perm: static python tuple of ints."""
    N, C = x.shape
    assert N % block_rows == 0
    kernel = functools.partial(_permute_kernel, perm=tuple(int(p) for p in perm))
    return pl.pallas_call(
        kernel,
        grid=(N // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, C), lambda i: (i, 0),
                               memory_space=pltpu.VMEM)],
        out_specs=pl.BlockSpec((block_rows, C), lambda i: (i, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((N, C), x.dtype),
        interpret=interpret,
    )(x)
