"""Pallas TPU kernel: AgileNN channel split via static permutation.

The deployed split is a channel gather: out[..., c] = in[..., perm[c]],
then a slice into (local k, remote C-k).  Because the permutation is
static (fixed at training time — that is the point of the disorder loss),
it compiles to a constant-index gather over the lane dimension; the
kernel processes (rows, C) tiles and emits the permuted tile, so split
costs one VMEM pass and zero compute.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import pad_rows_to_grid


def _permute_kernel(x_ref, o_ref, *, perm: tuple):
    x = x_ref[...]                                       # (rows, C)
    cols = [x[:, p:p + 1] for p in perm]                 # static gather
    o_ref[...] = jnp.concatenate(cols, axis=1)


def channel_permute_tpu(x, perm, *, block_rows: int = 256,
                        interpret: bool = False):
    """x: (N, C); perm: static python tuple of ints.

    N may be any positive row count: the grid is zero-padded to a whole
    number of ``block_rows`` tiles and the result sliced back.
    """
    N, C = x.shape
    x, grid, block_rows = pad_rows_to_grid(x, block_rows)
    N_p = grid * block_rows
    kernel = functools.partial(_permute_kernel, perm=tuple(int(p) for p in perm))
    out = pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((block_rows, C), lambda i: (i, 0),
                               memory_space=pltpu.VMEM)],
        out_specs=pl.BlockSpec((block_rows, C), lambda i: (i, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((N_p, C), x.dtype),
        interpret=interpret,
    )(x)
    return out[:N]
