"""Jit'd wrapper: permute channels and split local/remote."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.topk_split.kernel import channel_permute_tpu


@partial(jax.jit, static_argnames=("perm", "k", "interpret"))
def split_op(x, *, perm: tuple, k: int, interpret: bool = True):
    """x: (..., C) -> (local (..., k), remote (..., C-k))."""
    shape = x.shape
    C = shape[-1]
    n = x.size // C
    n_p = -(-n // 8) * 8
    x2 = jnp.zeros((n_p, C), x.dtype).at[:n].set(x.reshape(n, C))
    y = channel_permute_tpu(x2, perm, block_rows=n_p, interpret=interpret)
    y = y[:n].reshape(shape)
    return y[..., :k], y[..., k:]
