"""Jit'd wrapper: permute channels and split local/remote."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.topk_split.kernel import channel_permute_tpu


@partial(jax.jit, static_argnames=("perm", "k", "interpret"))
def split_op(x, *, perm: tuple, k: int, interpret: bool = True):
    """x: (..., C) -> (local (..., k), remote (..., C-k))."""
    shape = x.shape
    C = shape[-1]
    n = x.size // C
    y = channel_permute_tpu(x.reshape(n, C), perm, interpret=interpret)
    y = y.reshape(shape)
    return y[..., :k], y[..., k:]
