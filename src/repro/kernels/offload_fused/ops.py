"""Jit'd wrappers for the fused offload pass.

``fused_offload_op`` drives the Pallas kernel (interpret-mode on CPU, the
correctness harness; compiled on TPU).  ``fused_offload_jnp`` is the jnp
fallback: the same one-pass unrolled nearest-center scan, fused by XLA —
unlike the seed two-pass path it never materializes the (..., C-k, L)
distance tensor, so it is the substrate hot path on non-TPU backends.
``fused_offload`` picks per backend.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import nearest_center_scan
from repro.kernels.offload_fused.kernel import offload_fused_tpu


@partial(jax.jit, static_argnames=("perm", "k", "interpret"))
def fused_offload_op(x, centers, *, perm: tuple, k: int,
                     interpret: bool = True):
    """x: (..., C) -> (local (..., k), remote, indices, dequantized)."""
    shape = x.shape
    C = shape[-1]
    n = x.size // C
    outs = offload_fused_tpu(x.reshape(n, C), centers, perm=perm, k=k,
                             interpret=interpret)
    local, remote, idx, deq = outs
    lead = shape[:-1]
    return (local.reshape(lead + (k,)), remote.reshape(lead + (C - k,)),
            idx.reshape(lead + (C - k,)), deq.reshape(lead + (C - k,)))


@partial(jax.jit, static_argnames=("perm", "k"))
def fused_offload_jnp(x, centers, *, perm: tuple, k: int):
    """jnp fallback: identical outputs, single pass over the features."""
    y = jnp.take(x, jnp.asarray(perm), axis=-1)
    local, remote = y[..., :k], y[..., k:]
    best_i, best_v = nearest_center_scan(remote.astype(jnp.float32),
                                         centers.astype(jnp.float32))
    return local, remote, best_i, best_v.astype(x.dtype)


def fused_offload(x, centers, *, perm: tuple, k: int):
    """Backend dispatch: compiled Pallas on TPU, fused jnp elsewhere."""
    if jax.default_backend() == "tpu":
        return fused_offload_op(x, centers, perm=perm, k=k, interpret=False)
    return fused_offload_jnp(x, centers, perm=perm, k=k)
