"""Pure-jnp oracle for the fused permute->split->quantize kernel."""
from __future__ import annotations

import jax.numpy as jnp


def offload_fused_ref(x, centers, perm, k: int):
    """x: (..., C) -> (local, remote, indices, dequantized)."""
    y = jnp.take(x, jnp.asarray(perm), axis=-1)
    local, remote = y[..., :k], y[..., k:]
    d2 = (remote[..., None].astype(jnp.float32)
          - centers.astype(jnp.float32)) ** 2
    idx = jnp.argmin(d2, axis=-1).astype(jnp.int32)
    deq = jnp.take(centers, idx).astype(x.dtype)
    return local, remote, idx, deq
