"""Pallas TPU kernel: fused AgileNN online offload pass.

One VMEM pass over (rows, C) feature tiles performs the whole device-side
offload transform:

  channel-permute (static gather, fixed at training time)
    -> (local k, remote C-k) split
    -> nearest-center quantization of the remote half
       (int32 index + dequantized value)

This replaces the seed's slice-and-concat permute kernel plus a second
full quantization pass: the feature stream is read from HBM exactly once,
and the codebook (L <= 16 centers) is broadcast into VREGs.  Row counts
that are not a multiple of ``block_rows`` are zero-padded to the grid and
sliced back, so arbitrary batch x spatial shapes are accepted.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import nearest_center_scan, pad_rows_to_grid


def _fused_kernel(x_ref, centers_ref, local_ref, remote_ref, idx_ref,
                  deq_ref, *, perm: tuple, k: int):
    x = x_ref[...]                                       # (rows, C)
    cols = [x[:, p:p + 1] for p in perm]                 # static gather
    y = jnp.concatenate(cols, axis=1)
    local_ref[...] = y[:, :k]
    r = y[:, k:]
    remote_ref[...] = r
    centers = centers_ref[...].astype(jnp.float32)       # (1, L)
    best_i, best_v = nearest_center_scan(r.astype(jnp.float32),
                                         centers.reshape(-1))
    idx_ref[...] = best_i
    deq_ref[...] = best_v.astype(deq_ref.dtype)


def offload_fused_tpu(x, centers, *, perm, k: int, block_rows: int = 256,
                      interpret: bool = False):
    """x: (N, C); centers: (L,); perm: static python tuple of ints.

    Returns (local (N, k), remote (N, C-k), indices int32, dequantized),
    all in one pass.  N may be any positive row count.
    """
    N, C = x.shape
    L = centers.shape[0]
    x, grid, block_rows = pad_rows_to_grid(x, block_rows)
    N_p = grid * block_rows
    kernel = functools.partial(
        _fused_kernel, perm=tuple(int(p) for p in perm), k=k)
    row_spec = lambda w: pl.BlockSpec((block_rows, w), lambda i: (i, 0),
                                      memory_space=pltpu.VMEM)
    local, remote, idx, deq = pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            row_spec(C),
            pl.BlockSpec((1, L), lambda i: (0, 0), memory_space=pltpu.VMEM),
        ],
        out_specs=[row_spec(k), row_spec(C - k), row_spec(C - k),
                   row_spec(C - k)],
        out_shape=[
            jax.ShapeDtypeStruct((N_p, k), x.dtype),
            jax.ShapeDtypeStruct((N_p, C - k), x.dtype),
            jax.ShapeDtypeStruct((N_p, C - k), jnp.int32),
            jax.ShapeDtypeStruct((N_p, C - k), x.dtype),
        ],
        interpret=interpret,
    )(x, centers.reshape(1, L))
    return local[:N], remote[:N], idx[:N], deq[:N]
