"""Jit'd wrapper: quantize an arbitrary-shape feature tensor."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.quantize.kernel import quantize_tpu


@partial(jax.jit, static_argnames=("interpret",))
def quantize_op(x, centers, *, interpret: bool = True):
    """x: any shape; centers: (L,).  Returns (indices, dequantized)."""
    shape = x.shape
    n = x.size
    # pack into (rows, 128) lanes with padding; row padding to the tile
    # grid is handled inside the kernel
    w = 128
    rows = -(-n // w)
    flat = jnp.zeros((rows * w,), x.dtype).at[:n].set(x.reshape(-1))
    x2 = flat.reshape(rows, w)
    idx2, deq2 = quantize_tpu(x2, centers, interpret=interpret)
    idx = idx2.reshape(-1)[:n].reshape(shape)
    deq = deq2.reshape(-1)[:n].reshape(shape)
    return idx, deq
