"""Pallas TPU kernel: learned scalar quantization of offloaded features.

The AgileNN runtime hot spot on the serving side: for every feature
element, find the nearest codebook center, emit the int8 index and the
dequantized value in one pass.  VPU-bound; the codebook (L <= 16 centers)
is broadcast from SMEM-resident operands into VREGs, the feature stream
is tiled through VMEM in (rows, 128) blocks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import nearest_center_scan, pad_rows_to_grid


def _quant_kernel(x_ref, centers_ref, idx_ref, deq_ref):
    x = x_ref[...].astype(jnp.float32)                     # (rows, 128)
    centers = centers_ref[...].astype(jnp.float32)         # (1, n_centers)
    best_i, best_v = nearest_center_scan(x, centers.reshape(-1))
    idx_ref[...] = best_i
    deq_ref[...] = best_v.astype(deq_ref.dtype)


def quantize_tpu(x, centers, *, block_rows: int = 256, interpret: bool = False):
    """x: (N, 128k) 2D feature stream; centers: (L,).

    Returns (indices int32, dequantized x.dtype), same shape as x.
    N may be any positive row count (zero-padded to the tile grid).
    """
    N, W = x.shape
    assert W % 128 == 0, W
    x, n_tiles, block_rows = pad_rows_to_grid(x, block_rows)
    N_p = n_tiles * block_rows
    L = centers.shape[0]
    idx, deq = pl.pallas_call(
        _quant_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((block_rows, W), lambda i: (i, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, L), lambda i: (0, 0), memory_space=pltpu.VMEM),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, W), lambda i: (i, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((block_rows, W), lambda i: (i, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N_p, W), jnp.int32),
            jax.ShapeDtypeStruct((N_p, W), x.dtype),
        ],
        interpret=interpret,
    )(x, centers.reshape(1, L))
    return idx[:N], deq[:N]
