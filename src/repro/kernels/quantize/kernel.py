"""Pallas TPU kernel: learned scalar quantization of offloaded features.

The AgileNN runtime hot spot on the serving side: for every feature
element, find the nearest codebook center, emit the int8 index and the
dequantized value in one pass.  VPU-bound; the codebook (L <= 16 centers)
is broadcast from SMEM-resident operands into VREGs, the feature stream
is tiled through VMEM in (rows, 128) blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _quant_kernel(x_ref, centers_ref, idx_ref, deq_ref, *, n_centers: int):
    x = x_ref[...].astype(jnp.float32)                     # (rows, 128)
    centers = centers_ref[...].astype(jnp.float32)         # (1, n_centers)
    best_d = jnp.full(x.shape, jnp.inf, jnp.float32)
    best_i = jnp.zeros(x.shape, jnp.int32)
    best_v = jnp.zeros(x.shape, jnp.float32)
    for c in range(n_centers):                              # unrolled: L small
        cv = centers[0, c]
        d = (x - cv) ** 2
        take = d < best_d
        best_d = jnp.where(take, d, best_d)
        best_i = jnp.where(take, c, best_i)
        best_v = jnp.where(take, cv, best_v)
    idx_ref[...] = best_i.astype(jnp.int32)
    deq_ref[...] = best_v.astype(deq_ref.dtype)


def quantize_tpu(x, centers, *, block_rows: int = 256, interpret: bool = False):
    """x: (N, 128k) 2D feature stream; centers: (L,).

    Returns (indices int32, dequantized x.dtype), same shape as x.
    """
    N, W = x.shape
    assert W % 128 == 0, W
    assert N % block_rows == 0, (N, block_rows)
    L = centers.shape[0]
    grid = (N // block_rows,)
    kernel = functools.partial(_quant_kernel, n_centers=L)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, W), lambda i: (i, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, L), lambda i: (0, 0), memory_space=pltpu.VMEM),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, W), lambda i: (i, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((block_rows, W), lambda i: (i, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, W), jnp.int32),
            jax.ShapeDtypeStruct((N, W), x.dtype),
        ],
        interpret=interpret,
    )(x, centers.reshape(1, L))
