"""Pure-jnp oracle for the quantization kernel."""
from __future__ import annotations

import jax.numpy as jnp


def quantize_ref(x, centers):
    d2 = (x[..., None].astype(jnp.float32) - centers.astype(jnp.float32)) ** 2
    idx = jnp.argmin(d2, axis=-1).astype(jnp.int32)
    deq = jnp.take(centers, idx).astype(x.dtype)
    return idx, deq
