"""Shared helpers for the Pallas kernels and their jnp fallbacks."""
from __future__ import annotations

import jax.numpy as jnp


def round_up(x: int, m: int) -> int:
    """Smallest multiple of m that is >= x."""
    return -(-x // m) * m


def auto_page_size(S: int, candidates: tuple[int, ...] = (128, 64, 32)) -> int:
    """Largest candidate page size that divides a cache of width S into at
    least two pages; 0 when none does (callers take the dense path — a
    single page can never skip work)."""
    for p in candidates:
        if S % p == 0 and S // p >= 2:
            return p
    return 0


def nearest_center_scan(xf, centers_f32):
    """Unrolled nearest-center search (the quantization inner loop).

    xf: float32 array (any shape); centers_f32: 1-D float32 codebook with
    static length L (small: L <= 16, so the loop unrolls into VREG ops).
    Returns (indices int32, center values float32); ties resolve to the
    lowest index, bit-identical to argmin over squared distances.
    """
    best_d = jnp.full(xf.shape, jnp.inf, jnp.float32)
    best_i = jnp.zeros(xf.shape, jnp.int32)
    best_v = jnp.zeros(xf.shape, jnp.float32)
    for c in range(centers_f32.shape[0]):
        cv = centers_f32[c]
        d = (xf - cv) ** 2
        take = d < best_d
        best_d = jnp.where(take, d, best_d)
        best_i = jnp.where(take, c, best_i)
        best_v = jnp.where(take, cv, best_v)
    return best_i, best_v


def pad_rows_to_grid(x, block_rows: int):
    """Zero-pad the leading (row) axis to a whole number of tiles.

    block_rows is an upper bound: once the tile count is fixed, the tile
    size is rebalanced to the sublane-aligned minimum that still covers N,
    so row counts just above a tile boundary (e.g. 257 with 256-row tiles)
    don't pay for a nearly-empty padding tile.  Returns (x_padded,
    n_tiles, block_rows); callers slice outputs back to x.shape[0] rows.
    """
    N = x.shape[0]
    n_tiles = -(-N // block_rows)
    per_tile = -(-N // n_tiles)
    block_rows = -(-per_tile // 8) * 8
    N_p = n_tiles * block_rows
    if N_p != N:
        x = jnp.zeros((N_p,) + x.shape[1:], x.dtype).at[:N].set(x)
    return x, n_tiles, block_rows
