"""Pallas TPU paged decode-attention kernel (flash-decoding over KV pages).

One decode step attends a single query token per sequence against a
block-paged KV cache.  The grid walks (batch, kv_head, kv_page); the page
axis is sequential ("arbitrary"), so the online-softmax accumulators for
one (b, h) live in VMEM scratch across page visits.  `attend_len` is a
scalar-prefetch operand: pages at or past a row's valid depth are skipped
entirely (the early-exit that makes a 1024-wide cache cost only as much
as the row's actual context), and lanes past the depth inside the last
live page are masked.  GQA is handled in-kernel: the query tile holds all
G = Hq/Hkv group heads for one kv head, so every K/V page is streamed
from HBM exactly once per kv head.

Layout: q (B, Hkv, G, D); k/v (B, S, Hkv, D); out (B, Hkv, G, D).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_decode_kernel(attend_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *,
                         scale: float, page_size: int, n_pages: int):
    """Refs (VMEM): q (G, D); k/v (page_size, D); o (G, D).

    Scratch: m/l (G, 128) lane-replicated running max / normalizer,
    acc (G, D) unnormalized output accumulator.
    """
    b = pl.program_id(0)
    page = pl.program_id(2)
    attend = attend_ref[b]

    @pl.when(page == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(page * page_size < attend)
    def _visit():
        q = q_ref[...].astype(jnp.float32) * scale          # (G, D)
        k = k_ref[...].astype(jnp.float32)                  # (page, D)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        col = page * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        mask = col < attend
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                               # (G, 1)
        l_prev = l_ref[:, :1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.where(mask, jnp.exp(s - m_safe), 0.0)
        corr = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(
            corr * l_prev + jnp.sum(p, axis=-1, keepdims=True), l_ref.shape)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(page == n_pages - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, :1], 1e-20)
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_decode_attention_tpu(q, k, v, attend_len, *, page_size: int = 128,
                               interpret: bool = False):
    """q: (B, Hkv, G, D); k/v: (B, S, Hkv, D); attend_len: (B,) int32.
    S must be a multiple of page_size.  Returns (B, Hkv, G, D) in q.dtype.
    """
    B, Hkv, G, D = q.shape
    S = k.shape[1]
    assert S % page_size == 0, (S, page_size)
    n_pages = S // page_size
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_paged_decode_kernel, scale=scale,
                               page_size=page_size, n_pages=n_pages)

    def kv_page(b, h, p, attend):
        # clamp the fetched page to the row's last live one: Pallas skips
        # the HBM->VMEM copy when consecutive grid steps map to the same
        # block, so pages past attend_len cost no bandwidth (the compute
        # guard in the kernel body already skips their math)
        live = jnp.maximum((attend[b] + page_size - 1) // page_size, 1)
        return (b, jnp.minimum(p, live - 1), h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, n_pages),
        in_specs=[
            pl.BlockSpec((None, None, G, D), lambda b, h, p, _: (b, h, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((None, page_size, None, D), kv_page,
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((None, page_size, None, D), kv_page,
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((None, None, G, D),
                               lambda b, h, p, _: (b, h, 0, 0),
                               memory_space=pltpu.VMEM),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),   # running max (lane-replicated)
            pltpu.VMEM((G, 128), jnp.float32),   # running normalizer
            pltpu.VMEM((G, D), jnp.float32),     # unnormalized accumulator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(attend_len, jnp.int32), q, k, v)
