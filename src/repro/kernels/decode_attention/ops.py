"""Jit'd public wrappers for paged decode attention.

``paged_decode_attention_op`` drives the Pallas kernel (interpret-mode on
CPU, the correctness harness; compiled on TPU).  ``paged_decode_attention_jnp``
is the blocked fallback: a `lax.switch` over page-aligned prefix widths —
the branch for width W runs the *dense* reference math over k/v[:, :W],
where W is the smallest page multiple covering max(attend_len).  Because
masked tail keys feed exact zeros into every reduction, each branch is
bit-identical to the full-width dense path while doing only W/S of its
work, so swapping it under `nn.attention.decode_attention` cannot change
a single greedy token.  ``paged_decode_attention`` picks per backend and
falls back to the dense reference when the cache width doesn't page.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import auto_page_size
from repro.kernels.decode_attention.kernel import paged_decode_attention_tpu
from repro.kernels.decode_attention.ref import decode_attention_ref


def _width_ladder(S: int, page_size: int) -> tuple[int, ...]:
    """Page-multiple prefix widths that *divide* S.

    Only divisor widths are offered: XLA's CPU dot panelizes the
    contraction axis, and a prefix contraction over W is bit-identical to
    the full-width one (whose tail summands are exact zeros) only when W
    lands on a panel boundary — empirically, when W divides S.  Non-
    divisor widths (e.g. 768 of 1024) reassociate the accumulation and
    drift by ~1 ULP, which the greedy bit-compat contract forbids.
    `test_paged_jnp_bit_identical_to_dense` guards this assumption.
    """
    return tuple(W for W in range(page_size, S + 1, page_size)
                 if S % W == 0)


@partial(jax.jit, static_argnames=("page_size",))
def paged_decode_attention_jnp(q, k_cache, v_cache, attend_len, *,
                               page_size: int = 128):
    """Blocked-jnp paged decode attention, bit-identical to the dense ref.

    q: (B, 1, Hq, D); k/v_cache: (B, S, Hkv, D) with S a page multiple;
    attend_len: () or (B,) valid-slot counts.  Only the pages below the
    smallest ladder width covering max(attend_len) are touched.
    """
    S = k_cache.shape[1]
    assert S % page_size == 0 and S // page_size >= 1, (S, page_size)
    widths = _width_ladder(S, page_size)
    attend_len = jnp.asarray(attend_len)
    branch = jnp.clip(
        jnp.searchsorted(jnp.asarray(widths), jnp.max(attend_len),
                         side="left"),
        0, len(widths) - 1)

    def prefix(W, q, k, v, attend):
        return decode_attention_ref(
            q, jax.lax.slice_in_dim(k, 0, W, axis=1),
            jax.lax.slice_in_dim(v, 0, W, axis=1), attend)

    return jax.lax.switch(branch, [partial(prefix, W) for W in widths],
                          q, k_cache, v_cache, attend_len)


@partial(jax.jit, static_argnames=("page_size", "interpret"))
def paged_decode_attention_op(q, k_cache, v_cache, attend_len, *,
                              page_size: int = 128, interpret: bool = True):
    """Pallas path in the framework layout: q (B, 1, Hq, D) -> same."""
    B, _, Hq, D = q.shape
    Hkv = k_cache.shape[2]
    G = Hq // Hkv
    attend = jnp.broadcast_to(jnp.asarray(attend_len, jnp.int32), (B,))
    qg = q[:, 0].reshape(B, Hkv, G, D)
    out = paged_decode_attention_tpu(qg, k_cache, v_cache, attend,
                                     page_size=page_size,
                                     interpret=interpret)
    return out.reshape(B, 1, Hq, D)


def paged_decode_attention(q, k_cache, v_cache, attend_len, *,
                           page_size: int | None = None):
    """Backend dispatch for the serving decode hot path.

    Caches whose width pages cleanly run the paged path (compiled Pallas
    on TPU, bit-identical blocked jnp elsewhere); anything else takes the
    dense reference, so callers never pay page-padding for tiny caches.
    """
    S = k_cache.shape[1]
    page = page_size or auto_page_size(S)
    backend = jax.default_backend()
    if not page:
        return decode_attention_ref(q, k_cache, v_cache, attend_len)
    if backend == "tpu":
        return paged_decode_attention_op(q, k_cache, v_cache, attend_len,
                                         page_size=page, interpret=False)
    if backend != "cpu":
        # the divisor-ladder bit-identity is an XLA *CPU* dot-panelization
        # property; an unverified backend (GPU) gets the dense reference
        # rather than a maybe-ULP-off switch branch
        return decode_attention_ref(q, k_cache, v_cache, attend_len)
    return paged_decode_attention_jnp(q, k_cache, v_cache, attend_len,
                                      page_size=page)
