"""Dense decode-attention oracle.

This is the seed `decode_attention` math, verbatim: one float32 einsum of
the (B, 1) query block against the full cache width, a masked softmax,
and a second einsum against the values.  The paged paths must reproduce
it — the blocked-jnp fallback bit-exactly (it runs the same dense math
over a page-aligned prefix, and masked tail keys contribute exact zeros
to every reduction), the Pallas kernel to float tolerance (online
softmax re-orders the accumulation).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k_cache, v_cache, attend_len) -> jnp.ndarray:
    """q: (B, 1, Hq, D); k/v_cache: (B, S, Hkv, D); attend_len: () or (B,)
    count of valid cache slots per row.  Returns (B, 1, Hq, D) in q.dtype.
    """
    B, _, Hq, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, 1, Hkv, G, D)
    s = jnp.einsum("bthgd,bshd->bhgts", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale      # (B,Hkv,G,1,S)
    attend_len = jnp.asarray(attend_len)
    if attend_len.ndim == 0:
        valid = jnp.arange(S) < attend_len                   # broadcast over S
    else:
        valid = (jnp.arange(S)[None, :]
                 < attend_len[:, None])[:, None, None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgts,bshd->bthgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, Hq, D).astype(q.dtype)
