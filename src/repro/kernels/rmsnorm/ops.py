"""Jit'd wrapper for the fused RMSNorm kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.kernel import rmsnorm_tpu


@partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm_op(x, scale, *, eps: float = 1e-6, interpret: bool = True):
    """x: (..., d)."""
    shape = x.shape
    d = shape[-1]
    n = x.size // d
    n_p = -(-n // 8) * 8
    x2 = jnp.zeros((n_p, d), x.dtype).at[:n].set(x.reshape(n, d))
    y = rmsnorm_tpu(x2, scale, eps=eps, block_rows=n_p, interpret=interpret)
    return y[:n].reshape(shape)
