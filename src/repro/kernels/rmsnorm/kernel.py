"""Pallas TPU kernel: fused RMSNorm.

Fuses square/mean/rsqrt/scale in one VMEM pass over (rows, d) tiles —
the memory-bound normalization that brackets every transformer sublayer.
d is the model width (lane-dim multiple of 128); rows tile the flattened
(batch*seq) axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                  # (rows, d)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_tpu(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
                interpret: bool = False):
    """x: (N, d); scale: (d,).  d must be a multiple of 128 on real TPUs."""
    N, d = x.shape
    assert N % block_rows == 0, (N, block_rows)
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(N // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, d), lambda i: (0, 0), memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((N, d), x.dtype),
        interpret=interpret,
    )(x, scale.reshape(1, d))
