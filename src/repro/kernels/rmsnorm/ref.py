"""Pure-jnp oracle for the fused RMSNorm kernel."""
from __future__ import annotations

import jax.numpy as jnp


def rmsnorm_ref(x, scale, *, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * (var + eps) ** -0.5 * scale.astype(jnp.float32)).astype(x.dtype)
