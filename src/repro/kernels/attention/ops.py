"""Jit'd public wrapper around the flash-attention Pallas kernel.

Handles layout (B, T, H, D) <-> (B, H, T, D), padding to block multiples,
and the interpret fallback (CPU validation; real TPUs compile the kernel).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.attention.kernel import flash_attention_tpu


@partial(jax.jit, static_argnames=("causal", "window", "q_block", "kv_block",
                                   "interpret"))
def flash_attention_op(q, k, v, *, causal: bool = True, window: int = 0,
                       q_block: int = 128, kv_block: int = 128,
                       interpret: bool = True):
    """q: (B, T, Hq, D); k/v: (B, S, Hkv, D) — framework layout."""
    B, T, Hq, D = q.shape
    S = k.shape[1]
    qb = min(q_block, _round_up(T, 8))
    kb = min(kv_block, _round_up(S, 8))
    Tp, Sp = _round_up(T, qb), _round_up(S, kb)
    qt = jnp.pad(q, ((0, 0), (0, Tp - T), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    kt = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    vt = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    # padded keys must never win the softmax: rely on causal mask for the
    # padded q rows; mask padded keys via window-independent causal bound
    # (padded k positions > any valid q position when causal). For
    # non-causal use, caller must pass exact multiples.
    out = flash_attention_tpu(qt, kt, vt, causal=causal, window=window,
                              q_block=qb, kv_block=kb, interpret=interpret)
    return out.transpose(0, 2, 1, 3)[:, :T]


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m
