"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, Hq, T, D); k/v: (B, Hkv, S, D) -> (B, Hq, T, D)."""
    B, Hq, T, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, Hkv, G, T, D).astype(jnp.float32)
    s = jnp.einsum("bhgtd,bhsd->bhgts", qg, k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(T)[:, None]
    k_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask, p, 0.0)
    o = jnp.einsum("bhgts,bhsd->bhgtd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, T, D).astype(q.dtype)
