"""Pallas TPU flash-attention kernel (causal, GQA, optional sliding window).

TPU-native adaptation of the attention hot spot: the grid walks
(batch*kv_head, q_block); each program streams KV blocks for its row of
queries through VMEM with an online-softmax accumulator held in VREGs.
Block shapes are MXU-aligned (last dim 128, sublane multiples of 8).

Layout: q (B, Hq, T, D), k/v (B, Hkv, S, D) — heads-major so a (T, D)
query tile and (S_blk, D) KV tiles are contiguous VMEM blocks.

GQA: the q block index ranges over Hq; kv index = hq * Hkv // Hq.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, causal: bool,
                 window: int, q_block: int, kv_block: int, seq_len: int):
    """One (q_block, D) tile of queries vs all KV blocks.

    Refs (VMEM): q (q_block, D); k/v (S, D); o (q_block, D).
    """
    qi = pl.program_id(2)
    q = q_ref[...].astype(jnp.float32) * scale          # (Tq, D)
    D = q.shape[-1]
    Tq = q.shape[0]
    n_kv = seq_len // kv_block

    q_pos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32, (Tq, 1), 0)

    def body(kv_i, carry):
        m_prev, l_prev, acc = carry
        k = k_ref[pl.ds(kv_i * kv_block, kv_block), :].astype(jnp.float32)
        v = v_ref[pl.ds(kv_i * kv_block, kv_block), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (Tq, Skv)
        k_pos = kv_i * kv_block + jax.lax.broadcasted_iota(
            jnp.int32, (1, kv_block), 1)
        mask = jnp.ones(s.shape, jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.exp(s - m_safe)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
        l_new = corr * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((Tq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((Tq, 1), jnp.float32)
    a0 = jnp.zeros((Tq, D), jnp.float32)
    if causal:
        # skip fully-masked KV blocks past the diagonal
        hi = jnp.minimum(n_kv, (qi + 1) * q_block // kv_block + 1)
    else:
        hi = n_kv
    m, l, acc = jax.lax.fori_loop(0, hi, body, (m0, l0, a0))
    l = jnp.maximum(l, 1e-20)
    o_ref[...] = (acc / l).astype(o_ref.dtype)


def flash_attention_tpu(q, k, v, *, causal: bool = True, window: int = 0,
                        q_block: int = 128, kv_block: int = 128,
                        interpret: bool = False):
    """q: (B, Hq, T, D); k/v: (B, Hkv, S, D).  T, S multiples of the blocks.

    Returns (B, Hq, T, D) in q.dtype.
    """
    B, Hq, T, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    assert T % q_block == 0 and S % kv_block == 0, (T, S, q_block, kv_block)
    group = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    grid = (B, Hq, T // q_block)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        q_block=q_block, kv_block=kv_block, seq_len=S)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, q_block, D),
                         lambda b, h, i: (b, h, i, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((None, None, S, D),
                         lambda b, h, i: (b, h // group, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((None, None, S, D),
                         lambda b, h, i: (b, h // group, 0, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((None, None, q_block, D),
                               lambda b, h, i: (b, h, i, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((B, Hq, T, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
