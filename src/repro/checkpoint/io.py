"""Checkpointing: flat-key npz save/restore of parameter pytrees."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    paths, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in paths:
        key = "/".join(_k(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _k(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    return str(p)


def save_checkpoint(path: str, tree) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez_compressed(path, **_flatten(tree))


def restore_checkpoint(path: str, like):
    """Restore into the structure of `like` (shape/dtype template)."""
    data = np.load(path)
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat_like:
        key = "/".join(_k(x) for x in p)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    _, treedef2 = jax.tree_util.tree_flatten(like)
    return jax.tree_util.tree_unflatten(treedef2, leaves)
