"""SGD with momentum + decoupled weight decay (paper §7 training setup).

NOTE: parameter pytrees may contain tuples as *structural* nodes (the
backbone's superblocks), so the update never uses tuple-leaf tricks —
momentum and params are computed with separate tree_maps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sgd_init(params):
    return {"momentum": jax.tree_util.tree_map(jnp.zeros_like, params)}


def sgd_update(params, grads, state, *, lr: float, momentum: float = 0.9,
               weight_decay: float = 5e-4):
    m_new = jax.tree_util.tree_map(
        lambda p, g, m: momentum * m + g + weight_decay * p,
        params, grads, state["momentum"])
    p_new = jax.tree_util.tree_map(lambda p, m: p - lr * m, params, m_new)
    return p_new, {"momentum": m_new}
