"""AdamW (used by the LM end-to-end driver).

NOTE: parameter pytrees may contain tuples as *structural* nodes (the
backbone's superblocks), so the update never uses tuple-leaf tricks —
each state component is computed with its own tree_map.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state, *, lr: float, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1):
    step = state["step"] + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    m_new = jax.tree_util.tree_map(
        lambda g, m: b1 * m + (1 - b1) * g.astype(jnp.float32), grads, state["m"])
    v_new = jax.tree_util.tree_map(
        lambda g, v: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        grads, state["v"])

    def upd(p, m, v):
        update = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        p32 = p.astype(jnp.float32)
        return (p32 - lr * (update + weight_decay * p32)).astype(p.dtype)

    p_new = jax.tree_util.tree_map(upd, params, m_new, v_new)
    return p_new, {"m": m_new, "v": v_new, "step": step}
