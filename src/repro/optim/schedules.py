"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, base_lr: float, warmup: int, total: int,
                    min_lr: float = 0.0):
    step = jnp.asarray(step, jnp.float32)
    warm = base_lr * step / jnp.maximum(warmup, 1)
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_lr + 0.5 * (base_lr - min_lr) * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < warmup, warm, cos)


def step_decay(step, *, base_lr: float, decay: float = 0.1,
               milestones: tuple = (100, 150)):
    lr = jnp.full_like(jnp.asarray(step, jnp.float32), base_lr)
    for m in milestones:
        lr = jnp.where(step >= m, lr * decay, lr)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    import jax
    leaves = jax.tree_util.tree_leaves(grads)
    norm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm
