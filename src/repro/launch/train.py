"""Production training launcher.

On a real pod slice this runs the sharded train step; on this CPU
container use --dry-run (equivalent to repro.launch.dryrun) or --local to
execute a reduced config for a few real steps on host devices.

  python -m repro.launch.train --arch qwen2-0.5b --shape train_4k --dry-run
  python -m repro.launch.train --arch qwen2-0.5b --local --steps 10
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--local", action="store_true",
                    help="run a reduced config for real on host devices")
    ap.add_argument("--steps", type=int, default=10)
    args = ap.parse_args(argv)

    if args.dry_run:
        from repro.launch import dryrun
        rec = dryrun.run_one(args.arch, args.shape, multi_pod=args.multi_pod)
        print(rec)
        return 0

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.configs.shapes import get_shape
    from repro.launch.steps import make_train_step
    from repro.models import backbone as bb

    cfg = get_config(args.arch)
    if args.local:
        cfg = cfg.reduced()
    shape = get_shape(args.shape)
    key = jax.random.PRNGKey(0)
    params = bb.init_params(cfg, key)
    opt = {"momentum": jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p), params)}
    B, T = (4, 32) if args.local else (shape.global_batch, shape.seq_len)
    import dataclasses
    local_shape = dataclasses.replace(shape, global_batch=B, seq_len=T)
    step = jax.jit(make_train_step(cfg, local_shape, lr=1e-3))

    for i in range(args.steps):
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(i), (B, T), 0, cfg.vocab),
                 "labels": jax.random.randint(jax.random.PRNGKey(i), (B, T), 0, cfg.vocab)}
        if cfg.vlm is not None:
            batch["patches"] = jax.random.normal(
                key, (B, cfg.vlm.n_patches, cfg.vlm.vision_dim))
        if cfg.encdec is not None:
            batch["frames"] = jax.random.normal(
                key, (B, cfg.encdec.n_frames, cfg.d_model))
        t0 = time.time()
        params, opt, metrics = step(params, opt, batch)
        print(f"step {i} loss {float(metrics['loss']):.4f} "
              f"({time.time() - t0:.2f}s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
