"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and dump memory/cost/collective analyses.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]

The os.environ line below MUST precede any jax import: jax locks the
device count at first backend init.  (It lives only here — tests/benches
see the single real CPU device.)
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.shapes import SHAPES, get_shape
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import lower_step
from repro.roofline.analysis import analyze_compiled

# documented skips (DESIGN.md §4): enc-dec audio family has no meaningful
# 500k-token autoregressive decode.
SKIPS = {("whisper-tiny", "long_500k"): "enc-dec audio: bounded decoder; see DESIGN.md"}


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            skip_compile: bool = False, optimized: bool = False) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    strategy = None
    if optimized:
        from repro.launch.steps import OPTIMIZED_STRATEGIES
        strategy = OPTIMIZED_STRATEGIES.get((arch, shape_name))
    t0 = time.time()
    lowered, meta = lower_step(cfg, shape, mesh, strategy=strategy)
    t_lower = time.time() - t0
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "kind": meta["kind"], "lower_s": round(t_lower, 1),
           "strategy": "optimized" if strategy is not None else "baseline"}
    if skip_compile:
        return rec
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)
    mem = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
    }
    rec.update(analyze_compiled(compiled, mesh=mesh))
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--skip-compile", action="store_true",
                    help="lower only (fast sanity sweep)")
    ap.add_argument("--optimized", action="store_true",
                    help="use OPTIMIZED_STRATEGIES for the §Perf hillclimb pairs")
    args = ap.parse_args(argv)

    pairs = []
    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    for a in archs:
        for s in shapes:
            pairs.append((a, s))

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    failures = 0
    for multi_pod in meshes:
        for a, s in pairs:
            if (a, s) in SKIPS:
                results.append({"arch": a, "shape": s,
                                "mesh": "2x16x16" if multi_pod else "16x16",
                                "skipped": SKIPS[(a, s)]})
                print(f"SKIP  {a:24s} {s:12s} ({SKIPS[(a, s)]})")
                continue
            try:
                rec = run_one(a, s, multi_pod=multi_pod,
                              skip_compile=args.skip_compile,
                              optimized=args.optimized)
                results.append(rec)
                mem = rec.get("memory", {}).get("peak_bytes")
                mem_s = f"peak/dev {mem/2**30:.2f}GiB" if mem else ""
                print(f"OK    {a:24s} {s:12s} mesh={rec['mesh']} "
                      f"lower={rec['lower_s']}s "
                      f"compile={rec.get('compile_s','-')}s {mem_s}")
            except Exception as e:  # noqa: BLE001
                failures += 1
                results.append({"arch": a, "shape": s,
                                "mesh": "2x16x16" if multi_pod else "16x16",
                                "error": f"{type(e).__name__}: {e}"})
                print(f"FAIL  {a:24s} {s:12s}: {type(e).__name__}: {e}")
                traceback.print_exc(limit=3)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
