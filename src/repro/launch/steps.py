"""Distributed step functions + ShapeDtypeStruct input specs for the
dry-run and the launchers.

train_step: SGD-momentum with gradient accumulation over microbatches
(lax.scan) — the microbatch count scales with d_model so jamba/arctic
activations fit per-device HBM (see n_microbatches).

serve_step: one-token decode against the (sharded) cache.
prefill_step: context ingestion returning last logits + cache.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape
from repro.launch.mesh import data_axes
from repro.launch.partition import batch_spec, cache_shardings, param_shardings, replicated
from repro.models import backbone as bb


# --------------------------------------------------------------- strategy --
import dataclasses


@dataclasses.dataclass(frozen=True)
class Strategy:
    """Sharding strategy knob set (§Perf hillclimbs).

    model_axes: mesh axes carrying tensor parallelism for params
      ()                 -> pure data parallel (H1: small dense models)
      ("model",)         -> baseline 1D TP
      ("data", "model")  -> all-chip TP (H3: big-model decode)
    fsdp: override FSDP weight sharding (None = per-kind default)
    expert_data_sharding: resident 2D expert placement — experts over the
      data axes x expert-ff over model axes; removes per-microbatch FSDP
      gathers of expert weights (H2: arctic train)
    n_micro: gradient-accumulation override
    """
    model_axes: tuple = ("model",)
    fsdp: "bool | None" = None
    expert_data_sharding: bool = False
    n_micro: "int | None" = None
    bf16_grads: bool = False   # cast grads to bf16 before the all-reduce

    def batch_axes(self, mesh) -> tuple:
        return tuple(a for a in mesh.axis_names if a not in self.model_axes)


BASELINE = Strategy()

# beyond-paper optimized strategies from the §Perf hillclimb (EXPERIMENTS.md)
OPTIMIZED_STRATEGIES: dict[tuple, Strategy] = {
    # H1: pure DP, replicated fp32 params.  (The bf16-grad-all-reduce
    # iteration was REFUTED: GSPMD reduces gradients inside backprop,
    # before any post-hoc cast — EXPERIMENTS.md §Perf H1 iter 2.)
    ("qwen2-0.5b", "train_4k"): Strategy(model_axes=(), fsdp=False),
    # H2: resident 2D expert sharding + reduced grad accumulation
    ("arctic-480b", "train_4k"): Strategy(expert_data_sharding=True, n_micro=4),
    # H3: all-chip tensor parallelism, resident weights
    ("jamba-1.5-large-398b", "decode_32k"): Strategy(
        model_axes=("data", "model"), fsdp=False),
}


# ------------------------------------------------------------ microbatch ---
def n_microbatches(cfg: ArchConfig, shape: InputShape) -> int:
    """Gradient-accumulation factor: keeps per-device activation memory
    bounded for the wide architectures (power of two, divides batch)."""
    if shape.kind != "train":
        return 1
    if cfg.d_model >= 7000:
        n = 16
    elif cfg.d_model >= 4096:
        n = 8
    elif cfg.d_model >= 1536:
        n = 2
    else:
        n = 1
    while shape.global_batch % n:
        n //= 2
    return max(1, n)


# ------------------------------------------------------------ input specs --
def make_batch_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    """ShapeDtypeStructs for one training/prefill batch."""
    B, T = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    batch = {}
    if cfg.vlm is not None:
        P_img = cfg.vlm.n_patches
        batch["patches"] = jax.ShapeDtypeStruct((B, P_img, cfg.vlm.vision_dim),
                                                cfg.dtype)
        t_text = T - P_img
    else:
        t_text = T
    if cfg.encdec is not None:
        batch["frames"] = jax.ShapeDtypeStruct((B, cfg.encdec.n_frames, cfg.d_model),
                                               cfg.dtype)
    batch["tokens"] = jax.ShapeDtypeStruct((B, t_text), i32)
    if shape.kind == "train":
        batch["labels"] = jax.ShapeDtypeStruct((B, t_text), i32)
    return batch


def batch_shardings(cfg: ArchConfig, batch_specs: dict, mesh,
                    strategy: "Strategy | None" = None) -> dict:
    from jax.sharding import NamedSharding, PartitionSpec
    axes = (strategy or BASELINE).batch_axes(mesh)

    def sharding_for(v):
        # drop trailing batch axes until the global batch divides (e.g.
        # pure-DP batch 256 on the 512-chip multi-pod mesh shards over
        # (pod, data) = 32 and leaves "model" as pure replication)
        use = list(axes)
        while use:
            size = 1
            for a in use:
                size *= mesh.shape[a]
            if v.shape[0] % size == 0 and v.shape[0] >= size:
                return NamedSharding(mesh, PartitionSpec(
                    tuple(use), *([None] * (len(v.shape) - 1))))
            use.pop()
        return replicated(mesh)

    return {k: sharding_for(v) for k, v in batch_specs.items()}


def params_specs(cfg: ArchConfig) -> Any:
    """Parameter ShapeDtypeStructs WITHOUT allocating (eval_shape)."""
    return jax.eval_shape(
        lambda: bb.init_params(cfg, jax.random.PRNGKey(0)))


def opt_specs(params_sds) -> Any:
    return {"momentum": params_sds}


def cache_specs(cfg: ArchConfig, shape: InputShape) -> Any:
    long = shape.name == "long_500k"
    return jax.eval_shape(
        lambda: bb.init_cache(cfg, shape.global_batch, shape.seq_len,
                              long_context=long))


# ------------------------------------------------------------ step fns -----
def make_train_step(cfg: ArchConfig, shape: InputShape, *,
                    lr: float = 1e-3, momentum: float = 0.9,
                    weight_decay: float = 0.0,
                    n_micro_override: "int | None" = None,
                    bf16_grads: bool = False):
    """(params, opt, batch) -> (params, opt, metrics) with microbatching."""
    n_micro = n_micro_override or n_microbatches(cfg, shape)
    window = cfg.sliding_window

    def loss_fn(params, mb):
        loss, metrics = bb.forward_loss(cfg, params, mb, window=window)
        return loss, metrics

    def train_step(params, opt, batch):
        if n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
            if bf16_grads:
                # halve the gradient all-reduce payload (H1 iteration 2)
                grads = jax.tree_util.tree_map(
                    lambda g: g.astype(jnp.bfloat16), grads)
        else:
            def reshape_mb(x):
                return x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])
            mbs = jax.tree_util.tree_map(reshape_mb, batch)

            def micro(carry, mb):
                gacc, lacc = carry
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                gacc = jax.tree_util.tree_map(jnp.add, gacc, grads)
                return (gacc, lacc + loss / n_micro), None

            gacc0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(micro, (gacc0, jnp.zeros(())), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
            metrics = {}

        def new_m(p, g, m):
            g32 = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
            return (momentum * m.astype(jnp.float32) + g32).astype(m.dtype)

        def new_p(p, m):
            return (p.astype(jnp.float32) - lr * m.astype(jnp.float32)).astype(p.dtype)

        m_upd = jax.tree_util.tree_map(new_m, params, grads, opt["momentum"])
        p_upd = jax.tree_util.tree_map(new_p, params, m_upd)
        return p_upd, {"momentum": m_upd}, {"loss": loss}

    return train_step


def make_serve_step(cfg: ArchConfig, shape: InputShape):
    """(params, tokens (B,1), cache, cache_len) -> (logits, new_cache)."""

    def serve_step(params, tokens, cache, cache_len):
        return bb.decode_step(cfg, params, tokens, cache, cache_len)

    return serve_step


def make_prefill_step(cfg: ArchConfig, shape: InputShape):
    long = shape.name == "long_500k"

    def prefill_step(params, batch):
        logits, cache, total = bb.prefill(cfg, params, batch,
                                          long_context=long,
                                          max_len=shape.seq_len)
        return logits, cache

    return prefill_step


# ---------------------------------------------------------- jit assembly ---
def lower_step(cfg: ArchConfig, shape: InputShape, mesh, *,
               donate: bool = True, strategy: "Strategy | None" = None):
    """Build + lower the right step for (cfg, shape) on `mesh`.

    Returns (lowered, meta) where meta records what was lowered.
    """
    strategy = strategy or BASELINE
    p_sds = params_specs(cfg)
    # training: FSDP (ZeRO-3-style) param/grad/optimizer sharding over the
    # data axes on top of tensor parallelism; serving keeps params
    # tensor-parallel only (resident weights, no per-token gathers) UNLESS
    # the model doesn't fit a 16-way TP shard of v5e HBM (jamba/arctic:
    # ~400-500B params), in which case weights are 2D-sharded over
    # (data, model) and gathered per layer.
    msize = 1
    for a in strategy.model_axes:
        msize *= mesh.shape[a]
    serve_fsdp = _param_gib(p_sds) / max(msize, 1) > 12.0
    if shape.kind == "train":
        p_sh = param_shardings(p_sds, mesh, fsdp=True, strategy=strategy)
        step = make_train_step(cfg, shape, n_micro_override=strategy.n_micro,
                               bf16_grads=strategy.bf16_grads)
        o_sds = opt_specs(p_sds)
        o_sh = {"momentum": p_sh}
        b_sds = make_batch_specs(cfg, shape)
        b_sh = batch_shardings(cfg, b_sds, mesh, strategy)
        jitted = jax.jit(step,
                         in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None),
                         donate_argnums=(0, 1) if donate else ())
        with mesh:
            lowered = jitted.lower(p_sds, o_sds, b_sds)
        return lowered, {"kind": "train",
                         "n_micro": strategy.n_micro or n_microbatches(cfg, shape)}

    if shape.kind == "prefill":
        p_sh = param_shardings(p_sds, mesh, fsdp=serve_fsdp, strategy=strategy)
        step = make_prefill_step(cfg, shape)
        b_sds = make_batch_specs(cfg, shape)
        b_sh = batch_shardings(cfg, b_sds, mesh, strategy)
        c_sds = cache_specs(cfg, shape)
        c_sh = cache_shardings(c_sds, mesh, batch=shape.global_batch,
                               strategy=strategy)
        jitted = jax.jit(step, in_shardings=(p_sh, b_sh),
                         out_shardings=(batch_spec(mesh, 2), c_sh))
        with mesh:
            lowered = jitted.lower(p_sds, b_sds)
        return lowered, {"kind": "prefill"}

    # decode
    p_sh = param_shardings(p_sds, mesh, fsdp=serve_fsdp, strategy=strategy)
    step = make_serve_step(cfg, shape)
    b = shape.global_batch
    tok_sds = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    baxes = strategy.batch_axes(mesh)
    bsize = 1
    for a in baxes:
        bsize *= mesh.shape[a]
    if baxes and b >= bsize and b % bsize == 0:
        from jax.sharding import NamedSharding, PartitionSpec
        tok_sh = NamedSharding(mesh, PartitionSpec(baxes, None))
        logits_sh = NamedSharding(mesh, PartitionSpec(baxes, None))
    else:
        tok_sh = replicated(mesh)
        logits_sh = None
    c_sds = cache_specs(cfg, shape)
    c_sh = cache_shardings(c_sds, mesh, batch=b, strategy=strategy)
    len_sds = jax.ShapeDtypeStruct((), jnp.int32)
    jitted = jax.jit(step,
                     in_shardings=(p_sh, tok_sh, c_sh, replicated(mesh)),
                     out_shardings=(logits_sh, c_sh),
                     donate_argnums=(2,) if donate else ())
    with mesh:
        lowered = jitted.lower(p_sds, tok_sds, c_sds, len_sds)
    return lowered, {"kind": "decode"}


def _param_gib(p_sds) -> float:
    total = 0
    for leaf in jax.tree_util.tree_leaves(p_sds):
        total += leaf.size * leaf.dtype.itemsize
    return total / 2**30


def _dsize(mesh) -> int:
    s = 1
    for a in data_axes(mesh):
        s *= mesh.shape[a]
    return s
