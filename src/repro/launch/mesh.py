"""Production mesh definitions (TPU v5e pod slices).

Single pod: (data=16, model=16) = 256 chips.
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; `pod` is an outer
data-parallel axis (batch shards over ("pod", "data")).

Defined as functions, never module-level constants, so importing this
module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_serving_mesh(*, data: int | None = None, model: int = 1):
    """Serving mesh: the decode slot pool shards over ``data``, params go
    tensor-parallel over ``model``.  Defaults to every visible device on
    the data axis — on a single-device host this is the degenerate (1, 1)
    mesh, so the same code path serves laptops and pods."""
    if data is None:
        data = max(1, jax.device_count() // model)
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """The batch-sharding axes of a mesh from make_production_mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"


def axis_size(mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size
