"""Production serving launcher: prefill + decode loop for an architecture.

  python -m repro.launch.serve --arch mixtral-8x7b --shape decode_32k --dry-run
  python -m repro.launch.serve --arch qwen2-0.5b --local --tokens 8
  python -m repro.launch.serve --arch qwen2-0.5b --local --queue 24 \
      --lengths 8,16,32            # continuous-batching scheduler
"""
from __future__ import annotations

import argparse
import sys
import time


def _serve_queue(cfg, params, args) -> int:
    """Mixed-length request queue through the ServeEngine scheduler."""
    import numpy as np
    from repro.serve.engine import Request, ServeEngine
    from repro.serve.scheduler import SchedulerConfig

    lengths = tuple(int(x) for x in args.lengths.split(","))
    max_len = max(lengths) + args.tokens + 8
    eng = ServeEngine(cfg, params, max_len=max_len,
                      scheduler=SchedulerConfig(buckets=lengths))
    rng = np.random.RandomState(0)
    reqs = [Request(tokens=rng.randint(0, cfg.vocab, rng.choice(lengths)),
                    max_new_tokens=args.tokens)
            for _ in range(args.queue)]
    t0 = time.time()
    outs = eng.generate(reqs)
    dt = time.time() - t0
    toks = sum(len(c.tokens) for c in outs)
    print(f"served {len(reqs)} mixed-length requests "
          f"({toks} tokens) in {dt:.2f}s -> {toks / dt:.1f} tok/s")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--local", action="store_true")
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--queue", type=int, default=0, metavar="N",
                    help="serve N mixed-length requests through the "
                         "continuous-batching scheduler")
    ap.add_argument("--lengths", default="8,16,32",
                    help="comma-separated prompt-length mix for --queue")
    args = ap.parse_args(argv)

    if args.dry_run:
        from repro.launch import dryrun
        rec = dryrun.run_one(args.arch, args.shape, multi_pod=args.multi_pod)
        print(rec)
        return 0

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import backbone as bb

    cfg = get_config(args.arch)
    if args.local:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(0)
    params = bb.init_params(cfg, key)

    if args.queue:
        return _serve_queue(cfg, params, args)

    B, T = 2, 16
    batch = {"tokens": jax.random.randint(key, (B, T), 0, cfg.vocab)}
    if cfg.vlm is not None:
        batch["patches"] = jax.random.normal(
            key, (B, cfg.vlm.n_patches, cfg.vlm.vision_dim))
    if cfg.encdec is not None:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encdec.n_frames, cfg.d_model))
    logits, cache, total_T = bb.prefill(cfg, params, batch,
                                        max_len=T + args.tokens + 8)
    decode = jax.jit(lambda p, t, c, n: bb.decode_step(cfg, p, t, c, n))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    cl = total_T
    t0 = time.time()
    for i in range(args.tokens):
        logits, cache = decode(params, tok, cache, cl)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        cl += 1
    print(f"decoded {args.tokens} tokens x {B} in {time.time() - t0:.2f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
