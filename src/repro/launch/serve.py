"""Production serving launcher: prefill + decode loop for an architecture.

  python -m repro.launch.serve --arch mixtral-8x7b --shape decode_32k --dry-run
  python -m repro.launch.serve --arch qwen2-0.5b --local --tokens 8
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--local", action="store_true")
    ap.add_argument("--tokens", type=int, default=8)
    args = ap.parse_args(argv)

    if args.dry_run:
        from repro.launch import dryrun
        rec = dryrun.run_one(args.arch, args.shape, multi_pod=args.multi_pod)
        print(rec)
        return 0

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import backbone as bb

    cfg = get_config(args.arch)
    if args.local:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(0)
    params = bb.init_params(cfg, key)
    B, T = 2, 16
    batch = {"tokens": jax.random.randint(key, (B, T), 0, cfg.vocab)}
    if cfg.vlm is not None:
        batch["patches"] = jax.random.normal(
            key, (B, cfg.vlm.n_patches, cfg.vlm.vision_dim))
    if cfg.encdec is not None:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encdec.n_frames, cfg.d_model))
    logits, cache, total_T = bb.prefill(cfg, params, batch,
                                        max_len=T + args.tokens + 8)
    decode = jax.jit(lambda p, t, c, n: bb.decode_step(cfg, p, t, c, n))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    cl = total_T
    t0 = time.time()
    for i in range(args.tokens):
        logits, cache = decode(params, tok, cache, cl)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        cl += 1
    print(f"decoded {args.tokens} tokens x {B} in {time.time() - t0:.2f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
