"""Production serving launcher: prefill + decode loop for an architecture,
or a multi-client offload-gateway fleet run.

  python -m repro.launch.serve --arch mixtral-8x7b --shape decode_32k --dry-run
  python -m repro.launch.serve --arch qwen2-0.5b --local --tokens 8
  python -m repro.launch.serve --arch qwen2-0.5b --local --queue 24 \
      --lengths 8,16,32            # continuous-batching scheduler
  python -m repro.launch.serve --arch qwen2-0.5b --local --queue 24 \
      --mesh 4,2                   # slot pool sharded over a (4,2) mesh
  python -m repro.launch.serve --gateway 32 --requests 4 \
      --slo-ms 40                  # simulated weak-device fleet -> gateway
  python -m repro.launch.serve --gateway 32 --deadline-ms 150 \
      --faults "blackout:0.05:0.2;burst;corrupt:0:1:0.3" --fault-seed 7
                                   # chaos run: scripted faults, bounded
                                   # retries, graceful Local-NN fallback
  python -m repro.launch.serve --arch qwen2-0.5b --local --queue 24 \
      --stream --max-queue 8 --priority mixed --slo-ms 500
                                   # streaming frontend: bounded admission,
                                   # priority classes, typed rejections
  python -m repro.launch.serve --arch qwen2-0.5b --local --queue 24 \
      --stream --max-queue 8 --priority mixed --preempt \
      --journal journal.jsonl      # preemptible serving + crash-
                                   # consistent request journal

Flags are scope-checked at parse time: a flag that only applies to one
mode (e.g. --prefix-cache without --queue, or --slo-ms without
--gateway or --stream) is an immediate argparse error, not a silent
no-op.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def _dump_telemetry(tel, args) -> None:
    """End-of-run reporting, one path for every mode: the registry's
    Prometheus-style text goes to stderr, and --metrics-json /
    --trace-out persist the flat dump and the Chrome trace (load the
    trace in Perfetto / chrome://tracing)."""
    text = tel.metrics.prometheus_text()
    if text:
        sys.stderr.write(text)
    if args.metrics_json:
        with open(args.metrics_json, "w") as f:
            json.dump(tel.metrics.to_dict(), f, indent=1, default=str)
        print(f"wrote {args.metrics_json}", file=sys.stderr)
    if args.trace_out:
        tel.trace.write(args.trace_out)
        print(f"wrote {args.trace_out} "
              f"({len(tel.trace.spans)} spans)", file=sys.stderr)


def _serve_queue(cfg, params, args, tel) -> int:
    """Mixed-length request queue through the ServeEngine scheduler."""
    import numpy as np
    from repro.serve.engine import Request, ServeEngine
    from repro.serve.scheduler import SchedulerConfig

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_serving_mesh
        dims = [int(x) for x in args.mesh.split(",")]
        data, model = dims[0], (dims[1] if len(dims) > 1 else 1)
        mesh = make_serving_mesh(data=data, model=model)
    lengths = tuple(int(x) for x in args.lengths.split(","))
    rng = np.random.RandomState(0)
    shared = np.zeros((0,), np.int32)
    if args.prefix_cache:
        # shared-prefix workload: every prompt opens with the same
        # "system prompt" (page-aligned so it populates whole cache
        # pages) and diverges in its tail
        page = SchedulerConfig().page_size
        n_pages = max(1, max(lengths) // page)
        shared = rng.randint(0, cfg.vocab, n_pages * page)
    max_len = len(shared) + max(lengths) + args.tokens + 8
    eng = ServeEngine(cfg, params, max_len=max_len, mesh=mesh,
                      telemetry=tel,
                      scheduler=SchedulerConfig(
                          buckets=tuple(len(shared) + b for b in lengths),
                          overlap=not args.serialized,
                          prefix_cache=args.prefix_cache,
                          kv_tier_mb=args.kv_tier_mb))
    reqs = [Request(tokens=np.concatenate(
                        [shared, rng.randint(0, cfg.vocab,
                                             rng.choice(lengths))]),
                    max_new_tokens=args.tokens)
            for _ in range(args.queue)]
    t0 = time.time()
    outs = eng.generate(reqs)
    dt = time.time() - t0
    toks = sum(len(c.tokens) for c in outs)
    topo = (f" on a ({mesh.shape['data']},{mesh.shape['model']}) mesh"
            if mesh is not None else "")
    print(f"served {len(reqs)} mixed-length requests{topo} "
          f"({toks} tokens) in {dt:.2f}s -> {toks / dt:.1f} tok/s")
    # end-of-run stats (prefix-cache tiers included) all flow through the
    # metrics dump — no mode-specific ad-hoc stat printing
    m = tel.metrics
    m.gauge("serve.requests").set(len(reqs))
    m.gauge("serve.tokens").set(toks)
    m.gauge("serve.wall_s").set(dt)
    m.gauge("serve.tokens_per_s").set(toks / dt)
    eng.scheduler.export_metrics()
    _dump_telemetry(tel, args)
    return 0


def _serve_stream(cfg, params, args, tel) -> int:
    """Mixed-length queue through the overload-robust streaming frontend
    (bounded admission, priority classes, typed rejections)."""
    import numpy as np
    from repro.serve.engine import Request
    from repro.serve.frontend import (
        FrontendConfig, Overloaded, Priority, StreamingFrontend)
    from repro.serve.scheduler import SchedulerConfig

    lengths = tuple(int(x) for x in args.lengths.split(","))
    rng = np.random.RandomState(0)
    prios = (list(Priority) if args.priority == "mixed"
             else [Priority.parse(args.priority)])
    journal = None
    if args.journal:
        from repro.serve.recovery import RequestJournal
        journal = RequestJournal(args.journal, telemetry=tel)
    fe = StreamingFrontend(
        cfg, params,
        frontend=FrontendConfig(max_queue=args.max_queue,
                                slo_ms=args.slo_ms),
        sched=SchedulerConfig(buckets=lengths,
                              overlap=not args.serialized,
                              preempt=args.preempt),
        max_len=max(lengths) + args.tokens + 8, telemetry=tel,
        journal=journal)
    born = {}
    n_rej = 0
    t0 = time.time()
    for i in range(args.queue):
        req = Request(tokens=rng.randint(0, cfg.vocab, rng.choice(lengths)),
                      max_new_tokens=args.tokens)
        try:
            rid = fe.submit(req, prios[i % len(prios)])
            born[rid] = time.monotonic()
        except Overloaded as e:
            n_rej += 1
            print(f"  request {i}: {e}")
    results = fe.run()
    dt = time.time() - t0
    from repro.serve.frontend import FirstToken
    ttft = sorted((ev.t - born[ev.rid]) * 1e3 for ev in fe.events
                  if isinstance(ev, FirstToken))
    n_tok = sum(len(toks) for _, toks in results.values())
    by = {s: sum(st == s for st, _ in results.values())
          for s in ("served", "shed")}
    print(f"stream: {args.queue} requests (classes "
          f"{'/'.join(p.name.lower() for p in prios)}, "
          f"max_queue {args.max_queue}) -> "
          f"{by['served']} served, {by['shed']} shed, {n_rej} rejected; "
          f"{n_tok} tokens in {dt:.2f}s -> {n_tok / dt:.1f} tok/s"
          + (f"; ttft p50 {ttft[len(ttft) // 2]:.1f} ms" if ttft else ""))
    if journal is not None:
        journal.close()
        print(f"wrote {args.journal} ({len(journal.events)} journal "
              f"events)", file=sys.stderr)
    m = tel.metrics
    m.gauge("stream.tokens").set(n_tok)
    m.gauge("stream.wall_s").set(dt)
    m.gauge("stream.tokens_per_s").set(n_tok / dt)
    if ttft:
        m.gauge("stream.ttft_p50_ms").set(ttft[len(ttft) // 2])
    fe.sched.export_metrics()
    _dump_telemetry(tel, args)
    return 0


def _serve_gateway(args, tel) -> int:
    """Drive a simulated weak-device fleet through the offload gateway."""
    import jax
    from repro.configs.agilenn_cifar import gateway_demo_config
    from repro.core.agile import init_agile_params
    from repro.serve.faults import FaultInjector, parse_faults
    from repro.serve.gateway import (
        Fleet, GatewayConfig, OffloadGateway, mixed_fleet)

    cfg = gateway_demo_config()
    params = init_agile_params(cfg, jax.random.PRNGKey(0))
    specs = mixed_fleet(args.gateway, n_requests=args.requests,
                        slo_ms=args.slo_ms, deadline_ms=args.deadline_ms)
    fleet = Fleet(cfg, params, specs, seed=0)
    faults = (FaultInjector(parse_faults(args.faults), seed=args.fault_seed)
              if args.faults else None)
    report = OffloadGateway(
        cfg, params, fleet, GatewayConfig(batch_width=args.batch_width),
        faults=faults, telemetry=tel).run()
    mode = ("static rate" if args.slo_ms is None
            else f"adaptive rate, SLO {args.slo_ms:g} ms")
    if args.faults:
        mode += f", faults '{args.faults}' seed {args.fault_seed}"
    print(f"gateway: {args.gateway} clients x {args.requests} reqs "
          f"({mode}), pool width {args.batch_width}")
    # the report summary lands in the registry and flows out through the
    # same metrics dump every other mode uses
    m = tel.metrics
    for k, v in report.summary().items():
        if isinstance(v, dict):
            for sub, sv in v.items():
                m.gauge(f"gateway.{k}", channel=sub).set(sv)
        else:
            m.gauge(f"gateway.{k}").set(v)
    _dump_telemetry(tel, args)
    return 0


# every mode-scoped flag: (flag, argparse dest, mode that enables it).
# checked against the parser defaults at parse time so that a flag which
# cannot take effect fails fast instead of being silently ignored
_SCOPED_FLAGS = (
    ("--lengths", "lengths", "queue"),
    ("--mesh", "mesh", "queue"),
    ("--serialized", "serialized", "queue"),
    ("--prefix-cache", "prefix_cache", "queue"),
    ("--kv-tier-mb", "kv_tier_mb", "queue"),
    ("--stream", "stream", "queue"),
    ("--priority", "priority", "stream"),
    ("--max-queue", "max_queue", "stream"),
    ("--preempt", "preempt", "stream"),
    ("--journal", "journal", "stream"),
    ("--requests", "requests", "gateway"),
    ("--batch-width", "batch_width", "gateway"),
    ("--deadline-ms", "deadline_ms", "gateway"),
    ("--faults", "faults", "gateway"),
    ("--fault-seed", "fault_seed", "gateway"),
)


def _validate_flags(ap, args) -> None:
    """Parse-time scope check: reject flag combinations that would be
    silently inapplicable (each scoped flag must ride with the mode flag
    that reads it).  --slo-ms is dual-scope: gateway rate control or the
    streaming frontend's admission budget."""
    if args.gateway and args.queue:
        ap.error("--gateway and --queue are separate modes; pick one")
    on = {"queue": bool(args.queue), "gateway": bool(args.gateway),
          "stream": bool(args.queue and args.stream)}
    for flag, dest, scope in _SCOPED_FLAGS:
        if getattr(args, dest) != ap.get_default(dest) and not on[scope]:
            need = {"queue": "--queue N", "gateway": "--gateway N",
                    "stream": "--stream (with --queue N)"}[scope]
            ap.error(f"{flag} only applies with {need}")
    if args.slo_ms is not None and not (on["gateway"] or on["stream"]):
        ap.error("--slo-ms only applies with --gateway N or with "
                 "--queue N --stream")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--local", action="store_true")
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--queue", type=int, default=0, metavar="N",
                    help="serve N mixed-length requests through the "
                         "continuous-batching scheduler")
    ap.add_argument("--lengths", default="8,16,32",
                    help="comma-separated prompt-length mix for --queue")
    ap.add_argument("--mesh", default=None, metavar="DATA[,MODEL]",
                    help="serving mesh for --queue: the decode slot pool "
                         "shards over DATA devices, params go tensor-"
                         "parallel over MODEL (default: unsharded)")
    ap.add_argument("--serialized", action="store_true",
                    help="disable the overlapped prefill/decode pipeline "
                         "(A/B baseline: host syncs every round)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share prompt-prefix KV pages across --queue "
                         "admissions (the queue's prompts then open with "
                         "a common system prompt); hits seed resident "
                         "pages and prefill only the suffix")
    ap.add_argument("--kv-tier-mb", type=float, default=0.0,
                    help="host cold-tier budget (MiB) for prefix pages "
                         "demoted off the device, compressed with the "
                         "quantize+bit-pack payload codec (0: demoted "
                         "pages are dropped)")
    ap.add_argument("--stream", action="store_true",
                    help="serve --queue through the overload-robust "
                         "streaming frontend (typed per-token events, "
                         "bounded admission, priority shedding)")
    ap.add_argument("--priority", default="interactive",
                    choices=["interactive", "batch", "best-effort",
                             "mixed"],
                    help="admission class for --stream requests "
                         "('mixed' cycles all three)")
    ap.add_argument("--max-queue", type=int, default=None, metavar="N",
                    help="bound on admitted-but-unscheduled requests for "
                         "--stream; past it submissions are rejected "
                         "with a retry-after hint (default: unbounded)")
    ap.add_argument("--preempt", action="store_true",
                    help="let --stream suspend the lowest-priority pooled "
                         "request when an interactive arrival would "
                         "otherwise wait for a free slot; the victim "
                         "re-enters its class queue with its generated-"
                         "so-far tokens preserved and resumes bit-"
                         "identically")
    ap.add_argument("--journal", default=None, metavar="PATH",
                    help="append-only crash-consistent request journal "
                         "for --stream (crc32-framed JSONL of submit/"
                         "admit/chunk/preempt/finish events); replayable "
                         "via repro.serve.recovery.recover")
    ap.add_argument("--gateway", type=int, default=0, metavar="N",
                    help="simulate N weak-device clients through the "
                         "multi-client offload gateway")
    ap.add_argument("--requests", type=int, default=4,
                    help="inferences per gateway client")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="latency SLO: with --gateway, enables adaptive "
                         "rate control; with --stream, the queueing-"
                         "delay budget past which admission rejects")
    ap.add_argument("--batch-width", type=int, default=8,
                    help="gateway Remote-NN feature slot pool width")
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="scripted fault schedule for the gateway run: "
                         "';'-separated events, ':'-separated fields "
                         "(simulated seconds) — blackout[:t0:t1], "
                         "burst[:t0:t1[:pgb:pbg]] (Gilbert-Elliott burst "
                         "loss), degrade[:t0:t1[:scale[:loss]]], "
                         "devstall[:t0:t1[:s]], gwstall[:t0:t1[:s]], "
                         "corrupt[:t0:t1[:p]], stampede[:t0:t1[:f]] "
                         "(client arrivals compressed f-fold); e.g. "
                         "'blackout:0.05:0.2;burst;corrupt:0:1:0.3'")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the fault schedule's RNG streams "
                         "(same spec + seed replays identical faults)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline for gateway clients: the "
                         "radio stops retrying past it, late arrivals are "
                         "shed at admission, and the device degrades to "
                         "its Local-NN logits (default: no deadline)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome-trace JSON of the run's spans "
                         "(open in Perfetto / chrome://tracing); applies "
                         "to every mode")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the end-of-run metrics registry as flat "
                         "JSON (the Prometheus-style text always goes to "
                         "stderr); applies to every mode")
    args = ap.parse_args(argv)
    _validate_flags(ap, args)

    from repro.serve.telemetry import Telemetry
    tel = Telemetry(enabled=True)

    if args.gateway:
        return _serve_gateway(args, tel)
    if args.arch is None:
        ap.error("--arch is required (unless --gateway N is given)")

    if args.dry_run:
        from repro.launch import dryrun
        rec = dryrun.run_one(args.arch, args.shape, multi_pod=args.multi_pod)
        print(rec)
        return 0

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import backbone as bb

    cfg = get_config(args.arch)
    if args.local:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(0)
    params = bb.init_params(cfg, key)

    if args.queue:
        if args.stream:
            return _serve_stream(cfg, params, args, tel)
        return _serve_queue(cfg, params, args, tel)

    B, T = 2, 16
    batch = {"tokens": jax.random.randint(key, (B, T), 0, cfg.vocab)}
    if cfg.vlm is not None:
        batch["patches"] = jax.random.normal(
            key, (B, cfg.vlm.n_patches, cfg.vlm.vision_dim))
    if cfg.encdec is not None:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encdec.n_frames, cfg.d_model))
    with tel.span("prefill", track="engine", B=B, T=T):
        logits, cache, total_T = bb.prefill(cfg, params, batch,
                                            max_len=T + args.tokens + 8)
    decode = jax.jit(lambda p, t, c, n: bb.decode_step(cfg, p, t, c, n))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    cl = total_T
    t0 = time.time()
    with tel.span("decode", track="engine", tokens=args.tokens):
        for i in range(args.tokens):
            logits, cache = decode(params, tok, cache, cl)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            cl += 1
    dt = time.time() - t0
    tel.note_compiles("launch.decode_step", decode, shape=f"B{B}")
    tel.metrics.gauge("serve.tokens_per_s").set(args.tokens * B / dt)
    print(f"decoded {args.tokens} tokens x {B} in {dt:.2f}s")
    _dump_telemetry(tel, args)
    return 0


if __name__ == "__main__":
    sys.exit(main())
