"""Name-rule-based parameter partitioning (GSPMD PartitionSpecs).

Rules map parameter-path suffixes to *candidate* tensor axes (counted from
the end, so stacked superblock axes never shift a rule) to shard over the
"model" mesh axis — the first candidate that divides wins (e.g. mixtral's
8 experts don't divide a 16-way model axis, so its expert FFNs fall back
to tensor-parallel over d_ff).

FSDP mode (training): after the model axis is placed, the largest
remaining divisible axis is sharded over the data axes — ZeRO-3-style
weight/grad/optimizer sharding; GSPMD inserts the per-layer all-gather /
reduce-scatter.  Serving paths keep params tensor-parallel only (weights
stay resident; no per-token gathers).
"""
from __future__ import annotations

import re

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import axis_size, data_axes
from repro.nn.module import map_with_path

# (path regex, candidate axes-from-end for the "model" axis)
# order matters: first matching rule wins; first dividing candidate wins.
_RULES: list[tuple[str, tuple[int, ...]]] = [
    (r"embed/table$", (2, 1)),         # (V, d): vocab, else d
    (r"lm_head/w$", (1, 2)),           # (d, V)
    (r"attn/w[qkv]/w$", (1, 2)),       # (d, H*hd): column parallel
    (r"attn/w[qkv]/b$", (1,)),
    (r"attn/wo/w$", (2, 1)),           # (H*hd, d): row parallel
    (r"cross/w[qkv]/w$", (1, 2)),
    (r"cross/wo/w$", (2, 1)),
    (r"ffn/(gate|up)/w$", (1, 2)),
    (r"ffn/down/w$", (2, 1)),
    (r"ffn/fc1/w$", (1, 2)),
    (r"ffn/fc1/b$", (1,)),
    (r"ffn/fc2/w$", (2, 1)),
    (r"(dense_res|shared)/(gate|up)/w$", (1, 2)),
    (r"(dense_res|shared)/down/w$", (2, 1)),
    (r"moe/(gate|up)$", (3, 1, 2)),    # (E, d, ff): experts, else ff, else d
    (r"moe/down$", (3, 2, 1)),         # (E, ff, d): experts, else ff
    (r"mamba/in_proj/w$", (1, 2)),     # (d, 2*di)
    (r"mamba/out_proj/w$", (2, 1)),    # (di, d)
    (r"mamba/conv_w$", (1,)),          # (k, 1, di)
    (r"mamba/conv_b$", (1,)),
    (r"mamba/x_proj/w$", (2,)),        # (di, r+2s): row parallel
    (r"mamba/dt_proj/w$", (1,)),       # (r, di)
    (r"mamba/dt_bias$", (1,)),
    (r"mamba/A_log$", (2,)),           # (di, s)
    (r"mamba/D$", (1,)),
    (r"cell/w[qkv]/w$", (1, 2)),       # mLSTM projections
    (r"cell/out/w$", (2, 1)),
    (r"cell/wx/w$", (1, 2)),           # sLSTM gates (d, 4d)
    (r"cell/wx/b$", (1,)),
    (r"cell/wr/w$", (1, 2)),
    (r"vision_proj/w$", (1, 2)),
]

_FSDP_MIN_ELEMENTS = 1 << 18            # don't bother sharding small tensors


def _spec_for(path: str, shape, model_size: int, *, fsdp_axes=None,
              fsdp_size: int = 1, model_axes=("model",),
              expert_axes=None, expert_size: int = 1) -> P:
    ndim = len(shape)
    spec: list = [None] * ndim
    is_expert = bool(re.search(r"moe/(gate|up|down)$", path))
    if is_expert and expert_axes:
        # 2D resident expert sharding (§Perf H2): expert axis over
        # `expert_axes`, matmul axis over the model axes — no FSDP gathers.
        e_axis = ndim - 3
        if shape[e_axis] % expert_size == 0 and shape[e_axis] >= expert_size:
            spec[e_axis] = expert_axes
        ff_from_end = 1 if re.search(r"moe/(gate|up)$", path) else 2
        ff_axis = ndim - ff_from_end
        if model_size > 1 and shape[ff_axis] % model_size == 0:
            spec[ff_axis] = model_axes if len(model_axes) > 1 else model_axes[0]
        return P(*spec)
    if model_size > 1:
        for pattern, candidates in _RULES:
            if re.search(pattern, path):
                for axis_from_end in candidates:
                    axis = ndim - axis_from_end
                    if 0 <= axis < ndim and shape[axis] % model_size == 0 \
                            and shape[axis] >= model_size:
                        spec[axis] = (model_axes if len(model_axes) > 1
                                      else model_axes[0])
                        break
                break
    if fsdp_axes and _numel(shape) >= _FSDP_MIN_ELEMENTS:
        # largest remaining divisible axis over the data axes
        order = sorted(range(ndim), key=lambda i: -shape[i])
        for i in order:
            if spec[i] is None and shape[i] % fsdp_size == 0 \
                    and shape[i] >= fsdp_size:
                spec[i] = fsdp_axes
                break
    return P(*spec)


def _numel(shape) -> int:
    n = 1
    for s in shape:
        n *= s
    return n


def param_shardings(params_shape, mesh, *, fsdp: bool = False,
                    strategy=None):
    """Tree of NamedShardings aligned with a params pytree (arrays or
    ShapeDtypeStructs).  fsdp=True additionally shards params over the
    data axes (training).  `strategy` (launch.steps.Strategy) overrides
    the model-parallel axes / expert placement (§Perf hillclimbs)."""
    model_axes = ("model",)
    expert_axes = None
    if strategy is not None:
        model_axes = strategy.model_axes
        if strategy.expert_data_sharding:
            expert_axes = data_axes(mesh)
        if strategy.fsdp is not None:
            fsdp = strategy.fsdp
    model_size = 1
    for a in model_axes:
        model_size *= mesh.shape[a]
    daxes = tuple(a for a in mesh.axis_names if a not in model_axes)
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    e_size = 1
    if expert_axes:
        for a in expert_axes:
            e_size *= mesh.shape[a]

    def rule(path, leaf):
        return NamedSharding(mesh, _spec_for(
            path, leaf.shape, model_size,
            fsdp_axes=(daxes if (fsdp and daxes) else None), fsdp_size=dsize,
            model_axes=model_axes, expert_axes=expert_axes,
            expert_size=e_size))

    return map_with_path(rule, params_shape)


def pool_shardings(pool, mesh, *, model_axes=("model",)):
    """Shardings for a decode slot pool (`serve.scheduler`): every leaf
    leads with the slot axis, which shards over the data axes, so a slot
    lives wholly on one data shard and host-side evict/inject touches
    exactly that shard's rows.  Cache K/V leaves
    (n_sb, n_layer, S, W, Hkv, hd) carry the slot axis third and
    additionally go model-parallel over kv-heads when divisible, matching
    the tensor-parallel attention params.  Axes that don't divide stay
    replicated, so a 1-device mesh degenerates to the unsharded layout."""
    daxes = data_axes(mesh)
    dsize = axis_size(mesh, daxes)
    msize = axis_size(mesh, model_axes)
    dval = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    mval = (model_axes if len(model_axes) > 1
            else (model_axes[0] if model_axes else None))

    def rule(path, leaf):
        shape = leaf.shape
        if re.search(r"(^|/)(k|v)$", path):
            assert len(shape) == 6, (path, shape)
            spec: list = [None] * 6
            if dval is not None and shape[2] % dsize == 0:
                spec[2] = dval
            if mval is not None and msize > 1 and shape[4] % msize == 0:
                spec[4] = mval
            return NamedSharding(mesh, P(*spec))
        spec = [None] * len(shape)
        if dval is not None and shape[0] % dsize == 0:
            spec[0] = dval
        return NamedSharding(mesh, P(*spec))

    return map_with_path(rule, pool)


def batch_spec(mesh, ndim: int, *, batch_axis: int = 0) -> NamedSharding:
    """Shard dim `batch_axis` over the data axes."""
    spec = [None] * ndim
    spec[batch_axis] = data_axes(mesh)
    return NamedSharding(mesh, P(*spec))


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def cache_shardings(cache_shapes, mesh, *, batch: int, strategy=None):
    """Shardings for a decode cache pytree: batch dim over the batch axes
    when divisible; one model-parallel dim chosen by divisibility
    (kv-heads, then sequence/feature)."""
    model_axes = ("model",) if strategy is None else strategy.model_axes
    daxes = tuple(a for a in mesh.axis_names if a not in model_axes)
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    msize = 1
    for a in model_axes:
        msize *= mesh.shape[a]
    model_val = (model_axes if len(model_axes) > 1 else
                 (model_axes[0] if model_axes else None))

    def rule(path, leaf):
        shape = leaf.shape
        ndim = len(shape)
        spec = [None] * ndim
        b_idx = None
        for i, s in enumerate(shape):
            if s == batch:
                b_idx = i
                break
        if b_idx is not None and daxes and batch % dsize == 0 and batch >= dsize:
            spec[b_idx] = daxes
        start = (b_idx + 1) if b_idx is not None else 0
        cand = list(range(ndim - 1, start - 1, -1))
        if re.search(r"(^|/)(k|v|ck|cv)$", path) and ndim >= 3:
            cand = [ndim - 2, ndim - 3] + cand  # heads first, then sequence
        if model_val is not None:
            for i in cand:
                if spec[i] is None and shape[i] % msize == 0 and shape[i] >= msize:
                    spec[i] = model_val
                    break
        return NamedSharding(mesh, P(*spec))

    return map_with_path(rule, cache_shapes)
