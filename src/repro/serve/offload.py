"""AgileNN inference runtime (paper Figure 5, fused online path).

Given trained AgileNN parameters, runs the full deployment pipeline for a
batch of inputs and accounts every cost with the device model:

  device:  extractor
           fused offload pass (one kernel over the feature stream:
             channel-permute -> (local, remote) split ->
             nearest-center quantization indices + dequantized values)
           Local NN on the local half               (MACs -> t_compute)
           vectorized bit-pack (whole batch) -> per-sample LZW  (bytes)
  radio:   payload / bandwidth                     (t_tx)
  server:  Remote NN on the dequantized half       (t_server)
  device:  alpha-combine                           (negligible)

The fused pass is `repro.kernels.offload_fused` (Pallas on TPU, fused jnp
elsewhere); `measure_payload` makes exactly one device->host transfer per
batch and packs all samples in one numpy pass before the per-sample LZW
size accounting.  `run_offload_inference` returns predictions plus an
InferenceCost.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.compress.lzw import compress_payload, pack_indices_batch
from repro.compress.quantize import dequantize, quantization_bits
from repro.configs.agilenn_cifar import AgileNNConfig
from repro.core.agile import agile_forward, offload_payload_arrays
from repro.models.cnn import extractor_macs, local_nn_macs
from repro.serve.device_model import DeviceModel, InferenceCost


def local_path_macs(cfg: AgileNNConfig, feat_hw: int) -> int:
    """MACs of everything the weak device computes per inference
    (extractor + Local NN) — the one place this formula lives; the
    offload runtime and the gateway fleet both time/energy-account
    against it."""
    return (extractor_macs(cfg.image_size, 3, cfg.extractor_channels,
                           cfg.extractor_layers)
            + local_nn_macs(cfg.agile.k, cfg.n_classes, feat_hw,
                            cfg.local_hidden))


def remote_nn_macs(cfg: AgileNNConfig, feat_hw: int) -> int:
    """Approximate Remote NN MACs (inverted residual stack)."""
    C = cfg.extractor_channels - cfg.agile.k
    w, b = cfg.remote_width, cfg.remote_blocks
    total = feat_hw * feat_hw * C * w                      # stem 1x1
    s, c = feat_hw, w
    for i in range(b):
        cout = w * 2 if i >= b // 2 else w
        stride = 2 if i == b // 2 else 1
        mid = c * 4
        total += s * s * c * mid                           # pw1
        s //= stride
        total += s * s * mid * 9                           # dw 3x3
        total += s * s * mid * cout                        # pw2
        c = cout
    total += c * cfg.n_classes
    return total


def measure_payload(cfg: AgileNNConfig, params, images, *,
                    use_fused: bool = True) -> tuple[int, np.ndarray]:
    """Exact transmitted bytes: fused quantize -> batched bit-pack -> LZW.

    One device->host transfer and one vectorized packing pass for the
    whole batch; the LZW size is still accounted per sample (each sample
    is an independent radio payload)."""
    idx = np.asarray(offload_payload_arrays(cfg, params, images,
                                            use_fused=use_fused))
    bits = quantization_bits(params["quant"]["centers"].shape[0])
    total = 0
    for packed in pack_indices_batch(idx, bits):
        nbytes, _ = compress_payload(packed)
        total += nbytes
    return total, idx


def run_offload_inference(cfg: AgileNNConfig, params, images, *,
                          device: DeviceModel | None = None,
                          alpha_override=None):
    """Returns (predictions, InferenceCost averaged per sample)."""
    device = device or DeviceModel(cpu_hz=cfg.mcu_hz, link_bps=cfg.link_bps,
                                   macs_per_cycle=cfg.mcu_macs_per_cycle)
    B = images.shape[0]
    logits, internals = agile_forward(cfg, params, images, train=False,
                                      alpha_override=alpha_override)
    preds = np.asarray(jnp.argmax(logits, axis=-1))

    feat_hw = cfg.image_size // (2 ** cfg.extractor_layers)
    local_macs = local_path_macs(cfg, feat_hw)
    payload_bytes, _ = measure_payload(cfg, params, images)
    payload_per_sample = payload_bytes / B
    r_macs = remote_nn_macs(cfg, feat_hw)

    cost = InferenceCost(
        local_compute_s=device.compute_time(local_macs),
        tx_s=device.tx_time(payload_per_sample),
        server_s=device.server_time(r_macs),
        payload_bytes=payload_per_sample,
        local_macs=local_macs,
        remote_macs=r_macs,
    )
    return preds, cost


def energy_per_inference(cfg: AgileNNConfig, cost: InferenceCost, *,
                         device: DeviceModel | None = None) -> float:
    device = device or DeviceModel(cpu_hz=cfg.mcu_hz, link_bps=cfg.link_bps)
    return device.energy(cost.local_macs, cost.payload_bytes)
