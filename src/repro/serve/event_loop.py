"""The serving stack's one discrete-event clock.

Before this module the stack kept two clocks: the offload gateway ran a
private ``(time, prio, seq)`` heap inside its ``run()`` loop, and the
continuous-batching scheduler's overlap loop advanced an implicit
"round" clock of its own — gateway arrivals, decode rounds, deadline
evictions and stream callbacks could never be ordered against each
other.  `EventLoop` is that heap lifted out and shared: the gateway
pushes its arrival/serve/response events here, the streaming frontend's
simulated driver pushes request arrivals and scheduler rounds here, and
both hand the same ``now`` to the scheduler as its deadline clock — so
one timeline orders admission, decode, eviction and token delivery.

Ordering contract (identical to the gateway's historical heap, which
keeps every seeded simulation bit-identical through the refactor):
events pop in ``(time, prio, seq)`` order — time first, then priority
(the gateway uses the earliest deadline; 0.0 when none, so deadline-free
runs are untouched), then a monotone sequence number that keeps
same-instant same-priority events FIFO.  Runs are therefore
deterministic: the heap never compares payloads.
"""
from __future__ import annotations

import heapq
import itertools


class EventLoop:
    """A ``(time, prio, seq)`` discrete-event heap with a shared clock.

    ``now`` holds the timestamp of the most recently popped event (the
    simulation's current instant); passing ``lambda: loop.now`` as a
    scheduler's ``clock`` puts request deadlines on the same timeline as
    the events that age them.
    """

    def __init__(self, t0: float = 0.0):
        self.now = float(t0)
        self._heap: list[tuple] = []
        self._seq = itertools.count()

    def push(self, t: float, kind: str, data, prio: float = 0.0) -> None:
        heapq.heappush(self._heap, (t, prio, next(self._seq), kind, data))

    def pop(self) -> tuple[float, str, object]:
        """Pop the next event and advance ``now`` to its timestamp."""
        t, _, _, kind, data = heapq.heappop(self._heap)
        self.now = t
        return t, kind, data

    def peek_time(self) -> "float | None":
        return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
