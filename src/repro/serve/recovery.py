"""Crash-consistent request journal + replay recovery for the frontend.

The streaming frontend's degradation ladder bounds *overload*, but two
failure modes still lose admitted work outright: an engine crash
mid-round discards every in-flight request's partial tokens, and nothing
durable records what was admitted in the first place.  This module makes
"no admitted request is ever lost" a mechanical property:

  * **RequestJournal** — an append-only write-ahead log of typed events
    (``submit``/``admit``/``chunk``/``preempt``/``finish``) stamped on
    the frontend's shared clock timeline.  Each JSONL record carries a
    crc32 over its payload, so a torn final line (the partial write a
    real crash leaves) is detected and dropped rather than parsed —
    everything before it is intact by append-only discipline.  Journal
    writes reuse clock reads the frontend already makes and cost one
    dict + one flushed line each, cheap enough to leave on; with no
    path, events are kept in memory only (tests, benches).
  * **recovery_plan** — folds a journal into (a) requests that finished
    before the crash, with their full token streams reassembled from
    ``chunk`` records, and (b) replay items: admitted-but-unfinished
    requests as (original rid, Request, class, absolute deadline,
    tokens generated so far).  A request whose journaled tokens already
    exhaust its budget or end at EOS lost only its ``finish`` record to
    the crash — it resolves directly instead of replaying.
  * **recover** — installs every replay item into a fresh frontend
    under its original rid (`StreamingFrontend.restore`: admission
    control bypassed, pre-crash tokens resume through the scheduler's
    suspend/resume path), drains it, and merges with the pre-crash
    finishes.  The merge asserts disjointness: exactly one ``Finish``
    is ever delivered per rid across the crashed and recovered runs.

Greedy determinism is what makes replay *exact* rather than
best-effort: a resumed request prefills prompt + journaled tokens and
argmax-decodes the remainder, so the recovered stream is bit-identical
to the crash-free run (tested by sweeping `EngineCrash` across every
scheduling round of a pinned workload).
"""
from __future__ import annotations

import dataclasses
import json
import zlib
from typing import Optional

import numpy as np

from repro.serve import telemetry as _telemetry
from repro.serve.engine import Request
from repro.serve.frontend import Priority

EVENT_KINDS = ("submit", "admit", "chunk", "preempt", "finish")


class RequestJournal:
    """Append-only write-ahead request journal.

    Every record is one line: ``<crc32 hex> <canonical JSON>``, flushed
    on append so a crash can tear at most the line being written —
    which the crc then rejects on read.  ``events`` mirrors the records
    in memory (the only store when ``path`` is None), so an in-process
    recovery never re-parses the file.  With telemetry enabled, appends
    count into ``journal.events{ev=...}``; disabled telemetry costs
    nothing (no clock reads — timestamps come from the caller).
    """

    def __init__(self, path: Optional[str] = None, *, telemetry=None):
        self.path = path
        self.events: list[dict] = []
        self.tel = telemetry if telemetry is not None else _telemetry.default()
        self._f = open(path, "a", encoding="utf-8") if path else None

    def append(self, ev: str, rid: int, t: float, **fields) -> dict:
        assert ev in EVENT_KINDS, f"unknown journal event {ev!r}"
        rec = {"ev": ev, "rid": int(rid), "t": float(t), **fields}
        self.events.append(rec)
        if self._f is not None:
            body = json.dumps(rec, separators=(",", ":"), sort_keys=True)
            self._f.write(f"{zlib.crc32(body.encode()):08x} {body}\n")
            self._f.flush()
        if self.tel.enabled:
            self.tel.counter("journal.events", ev=ev).inc()
        return rec

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self) -> "RequestJournal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @staticmethod
    def read(path: str) -> list[dict]:
        """Parse a journal file, stopping at the first torn or corrupt
        line (crash consistency: append-only means everything before a
        bad line is intact; everything after it never happened)."""
        out: list[dict] = []
        with open(path, encoding="utf-8") as f:
            for line in f:
                parts = line.rstrip("\n").split(" ", 1)
                if len(parts) != 2:
                    break
                crc, body = parts
                try:
                    if int(crc, 16) != zlib.crc32(body.encode()):
                        break
                    rec = json.loads(body)
                except ValueError:
                    break
                if not isinstance(rec, dict) or rec.get("ev") not in \
                        EVENT_KINDS:
                    break
                out.append(rec)
        return out


# ------------------------------------------------------------- replay --


@dataclasses.dataclass(frozen=True)
class ReplayItem:
    """One admitted-but-unfinished request, ready to `restore`."""
    rid: int
    request: Request
    priority: Priority
    deadline_at: Optional[float]
    generated: np.ndarray            # journaled tokens (may be empty)


@dataclasses.dataclass
class RecoveryPlan:
    """What a journal implies: pre-crash resolutions and replay work."""
    finished: dict                   # rid -> (status, tokens)
    replay: list                     # [ReplayItem], submission order


def recovery_plan(events: list[dict]) -> RecoveryPlan:
    """Fold journal events into finished results + replay items.

    ``chunk`` records are concatenated per rid (each holds only the
    tokens newly published that round).  A rid with a ``finish`` record
    resolved before the crash; a rid whose journaled tokens already
    exhaust its budget or end at its EOS id lost only the finish record
    and resolves directly as served — replaying it would have nothing
    left to decode.  Everything else replays from prompt + journaled
    tokens under its original rid.
    """
    subs: dict[int, dict] = {}
    chunks: dict[int, list[int]] = {}
    finished: dict[int, tuple] = {}
    for rec in events:
        rid, ev = rec["rid"], rec["ev"]
        if ev == "submit":
            subs[rid] = rec
        elif ev == "chunk":
            chunks.setdefault(rid, []).extend(rec["toks"])
        elif ev == "finish":
            toks = np.asarray(chunks.get(rid, []), np.int32)
            finished[rid] = (rec["status"], toks[:rec["n"]])
    replay: list[ReplayItem] = []
    for rid, rec in subs.items():
        if rid in finished:
            continue
        gen = np.asarray(chunks.get(rid, []), np.int32)
        if len(gen) and (len(gen) >= rec["max_new"]
                         or int(gen[-1]) == rec["eos"]):
            finished[rid] = ("served", gen)     # finish record was the
            continue                            # only thing the crash ate
        req = Request(tokens=np.asarray(rec["prompt"], np.int32),
                      max_new_tokens=int(rec["max_new"]),
                      eos_id=int(rec["eos"]),
                      temperature=float(rec["temp"]))
        replay.append(ReplayItem(rid, req, Priority[rec["prio"]],
                                 rec.get("deadline"), gen))
    replay.sort(key=lambda it: it.rid)          # original admission order
    return RecoveryPlan(finished=finished, replay=replay)


def recover(fe, journal_or_events, *, drive=None) -> dict:
    """Reconstruct a crashed frontend's requests into ``fe`` and drain.

    ``journal_or_events`` is the crashed run's `RequestJournal` (or its
    raw event list / a `RequestJournal.read` result).  Every replay item
    is `restore`d under its original rid, the frontend is drained
    (``drive`` overrides ``fe.run()`` for virtual-clock drivers), and
    the results merge with the pre-crash finishes.  The merge asserts
    the two sets are disjoint — exactly-once completion delivery — and
    covers every journaled submission, so the return maps each admitted
    rid to its (status, tokens) with tokens bit-identical to a crash-
    free run.
    """
    events = (journal_or_events.events
              if isinstance(journal_or_events, RequestJournal)
              else list(journal_or_events))
    plan = recovery_plan(events)
    tel = fe.tel
    with tel.span("recovery.replay", track="recovery", cat="recovery",
                  n_replay=len(plan.replay),
                  n_finished=len(plan.finished)):
        for item in plan.replay:
            fe.restore(item.rid, item.request, item.priority,
                       deadline_at=item.deadline_at,
                       generated=item.generated)
        out = drive() if drive is not None else fe.run()
    if tel.enabled:
        tel.counter("recovery.replayed").inc(len(plan.replay))
        tel.counter("recovery.recovered_finished").inc(len(plan.finished))
    merged = dict(plan.finished)
    for rid, res in out.items():
        assert rid not in merged, \
            f"rid {rid} finished both before and after the crash"
        merged[rid] = res
    return merged
