"""Shared-prefix page cache for the decode slot pool: refcounted physical
pages with a quantized host tier.

At millions of clients most prompts share a head — a system prompt, a
few-shot header — so the K/V pages that head prefills into are identical
across admissions (pad-masked bucketed prefill is causal and
width-independent for real positions, so a page's values depend only on
the token prefix up to its end).  This module deduplicates that work the
same way AgileNN moves online cost into offline structure: pages are
content-addressed by a *chain hash* over the full token prefix, an
admission that finds its leading pages resident seeds them into its slot
and prefills only the suffix, and every page a live slot was built from
is pinned by refcount until the slot is released.

Ownership model (the scheduler's side of the contract is in
`serve.scheduler`):

  * **page table** — ``key -> _Entry``; the key of page p is
    ``H(key_{p-1} || tokens[p*page : (p+1)*page])``, so two prompts share
    page p only when they agree on *every* token before it (position
    matters: causal K/V is a function of the whole prefix, not the page's
    own tokens).  The page holding a prompt's final token is never
    shareable — the admission must compute at least the last position
    itself to produce its first-token logits.
  * **copy-on-write, hoisted to inject** — slots never alias pages: the
    pool's dense layout means a slot's first (and only) write below its
    prompt length is the inject scatter, so the "first divergent write"
    copy happens exactly once, at admission, by seeding private copies of
    the shared pages.  Decode then appends strictly above the prompt, so
    a slot can never mutate a shared page and readers need no fault path.
  * **refcounts** — ``pin(slot, ...)`` takes a reference on every
    shareable page of the slot's prompt (inserting pages the slot just
    prefilled); ``release(slot)`` drops them.  Pages with live references
    are never demoted or dropped, so a fetch for an occupied slot can
    always be served from the hot tier.
  * **two tiers** — hot pages are device arrays sliced per page; when the
    hot tier exceeds ``hot_pages``, cold (refcount-zero) pages demote LRU
    to a host tier compressed with the repo's transmission codec
    (`compress.quantize` uniform codebook + `compress.lzw` bit-packing) —
    the device->gateway payload machinery turned inward.  A hit on a cold
    page decompresses it back to the device.  The tier is *lossy* by
    design (``2**bits`` centers spanning the page's own value range), the
    same accuracy-for-bytes trade the paper makes on the link; runs that
    need bit-exact replay size ``hot_pages`` to their working set or set
    ``cold_bytes=0`` so cold pages drop instead of degrade.

Everything here is host-side bookkeeping plus whole-page device
slices/concats — no compiled program changes shape because of sharing,
which is what lets the scheduler's one-program-per-bucket discipline
survive intact.
"""
from __future__ import annotations

import hashlib

import jax.numpy as jnp
import numpy as np

from repro.compress.lzw import pack_indices, unpack_indices
from repro.compress.quantize import dequantize, hard_indices, quantizer_init


def page_keys(tokens, page_size: int) -> list[bytes]:
    """Chain-hash keys for every *shareable* page of a prompt.

    Key p digests the whole token prefix through page p (each digest
    extends the previous hash state), so equal keys imply equal prefixes
    — a page is only reusable where causal attention guarantees its K/V
    match.  Pages at or past the final token are excluded: the admission
    owns its last position (first-token logits come from it).
    """
    toks = np.ascontiguousarray(np.asarray(tokens, np.int64))
    n = max(0, (len(toks) - 1) // page_size)
    h = hashlib.sha1(np.int64(page_size).tobytes())
    keys = []
    for p in range(n):
        h.update(toks[p * page_size:(p + 1) * page_size].tobytes())
        keys.append(h.digest())
    return keys


class _Entry:
    """One physical page: device-resident K/V and/or a compressed host
    blob, pinned by the slots built from it."""

    __slots__ = ("key", "refs", "hot", "cold", "stamp")

    def __init__(self, key: bytes):
        self.key = key
        self.refs = 0        # live slots whose cache was seeded/built here
        self.hot = None      # {"k","v"}: (n_sb, n_attn, page, n_kv, hd)
        self.cold = None     # {"k","v"}: (payload, lo, hi) packed indices
        self.stamp = 0       # LRU tick


class PrefixCache:
    """Refcounted page table over prompt-prefix K/V, with a hot device
    tier and a quantized cold host tier.

    hot_pages:  device-resident page budget; referenced pages are pinned
                and may transiently overflow it.
    cold_bytes: host-tier payload budget for demoted pages (0: demotion
                drops the page outright).
    bits:       codebook bits per element in the cold tier (<= 8, the
                bit-packer's framing).
    """

    def __init__(self, page_size: int, *, hot_pages: int = 512,
                 cold_bytes: int = 0, bits: int = 8):
        assert page_size >= 1
        assert 1 <= bits <= 8, "cold tier packs <= 8 bits per element"
        self.page_size = page_size
        self.hot_pages = hot_pages
        self.cold_bytes = cold_bytes
        self.bits = bits
        self._index: dict[bytes, _Entry] = {}
        self._slot_keys: dict[int, list[bytes]] = {}
        self._parked: dict[object, list[bytes]] = {}
        self._tick = 0
        self._cold_used = 0
        self._page_shape = None      # (n_sb, n_attn, page, n_kv, hd)
        self._dtype = None
        self.stats = {"page_lookups": 0, "page_hits": 0, "inserts": 0,
                      "demotions": 0, "promotions": 0, "hot_drops": 0,
                      "cold_drops": 0}

    # ------------------------------------------------------------ queries --

    @property
    def hit_rate(self) -> float:
        """Pages served from the cache / shareable pages of admitted
        prompts (deterministic for a fixed workload + schedule)."""
        return self.stats["page_hits"] / max(1, self.stats["page_lookups"])

    @property
    def n_hot(self) -> int:
        return sum(1 for e in self._index.values() if e.hot is not None)

    @property
    def n_cold(self) -> int:
        return sum(1 for e in self._index.values() if e.cold is not None)

    @property
    def cold_used_bytes(self) -> int:
        return self._cold_used

    def lookup(self, tokens) -> tuple[list[bytes], int]:
        """(page keys of the prompt, length of the leading resident run).
        Pure query — admission stats are recorded by `record` only when a
        request is actually admitted, so re-planning the same queue head
        across rounds does not inflate the hit rate."""
        keys = page_keys(tokens, self.page_size)
        n = 0
        for k in keys:
            if k not in self._index:
                break
            n += 1
        return keys, n

    def record(self, n_pages: int, n_seeded: int) -> None:
        """Account one admission: n_pages shareable pages looked up,
        n_seeded of them served from the cache."""
        self.stats["page_lookups"] += n_pages
        self.stats["page_hits"] += n_seeded

    # ----------------------------------------------------------- transfer --

    def fetch(self, keys: list[bytes]) -> dict:
        """Concatenated device K/V for a resident run of pages (token
        axis 2), promoting cold pages back to the device on the way."""
        ks, vs = [], []
        for key in keys:
            e = self._index[key]
            self._touch(e)
            if e.hot is None:
                e.hot = {"k": self._decompress(e.cold["k"]),
                         "v": self._decompress(e.cold["v"])}
                self.stats["promotions"] += 1
            ks.append(e.hot["k"])
            vs.append(e.hot["v"])
        if len(ks) == 1:
            return {"k": ks[0], "v": vs[0]}
        return {"k": jnp.concatenate(ks, axis=2),
                "v": jnp.concatenate(vs, axis=2)}

    def pin(self, slot: int, keys: list[bytes], k_rows, v_rows) -> None:
        """Reference every shareable page of a freshly admitted slot,
        inserting the ones it prefilled itself.  k_rows/v_rows are the
        slot's cache rows, (n_sb, n_attn, W, n_kv, hd) with
        W >= len(keys) * page_size; per-page slices are device copies, so
        entries never alias (or pin) a slot's cache buffer."""
        assert slot not in self._slot_keys, f"slot {slot} already pinned"
        page = self.page_size
        for p, key in enumerate(keys):
            e = self._index.get(key)
            sl = (slice(None), slice(None), slice(p * page, (p + 1) * page))
            if e is None:
                e = _Entry(key)
                e.hot = {"k": jnp.copy(k_rows[sl]), "v": jnp.copy(v_rows[sl])}
                self._register_shape(e.hot["k"])
                self._index[key] = e
                self.stats["inserts"] += 1
            elif e.hot is None:
                # resident only as a cold blob: the slot's own rows hold
                # the bytes it was seeded from — rehydrate for free
                e.hot = {"k": jnp.copy(k_rows[sl]), "v": jnp.copy(v_rows[sl])}
            e.refs += 1
            self._touch(e)
        self._slot_keys[slot] = list(keys)
        self._enforce_budgets()

    def release(self, slot: int) -> None:
        """Drop the slot's references; unpinned pages become demotion
        candidates.  Unknown slots are a no-op (staging admissions that
        abort before finishing were never pinned)."""
        for key in self._slot_keys.pop(slot, []):
            e = self._index.get(key)
            if e is not None:
                e.refs -= 1
                assert e.refs >= 0, "refcount underflow"
        self._enforce_budgets()

    def park(self, slot: int, token) -> "object | None":
        """Transfer a slot's pins to a parked handle: the references move
        from the slot to ``token`` without ever dropping, so a suspended
        request's pages stay resident (never demoted — the fetch contract
        holds) while it waits to resume.  Returns the handle, or None when
        the slot held no pins.  The slot itself is left unpinned and free
        to re-admit."""
        keys = self._slot_keys.pop(slot, None)
        if not keys:
            return None
        assert token not in self._parked, f"park handle {token!r} in use"
        self._parked[token] = keys
        return token

    def unpark(self, token) -> None:
        """Drop a parked handle's references (the resumed admission has
        re-pinned through its own slot, or the suspension was discarded).
        Unknown handles are a no-op, mirroring `release`."""
        for key in self._parked.pop(token, []):
            e = self._index.get(key)
            if e is not None:
                e.refs -= 1
                assert e.refs >= 0, "refcount underflow"
        self._enforce_budgets()

    # ----------------------------------------------------------- internal --

    def _register_shape(self, leaf) -> None:
        if self._page_shape is None:
            self._page_shape = tuple(leaf.shape)
            self._dtype = leaf.dtype

    def _touch(self, e: _Entry) -> None:
        self._tick += 1
        e.stamp = self._tick

    def _compress(self, arr) -> tuple[bytes, float, float]:
        """Page array -> (bit-packed codebook indices, codebook range).
        The codebook is the transmission quantizer's uniform grid, fit to
        the page's own value range."""
        x = np.asarray(arr, np.float32)
        lo, hi = float(x.min()), float(x.max())
        if not hi > lo:
            hi = lo + 1.0
        qp = quantizer_init(1 << self.bits, lo, hi)
        idx = np.asarray(hard_indices(qp, jnp.asarray(x)))
        return pack_indices(idx, self.bits), lo, hi

    def _decompress(self, blob: tuple[bytes, float, float]):
        payload, lo, hi = blob
        qp = quantizer_init(1 << self.bits, lo, hi)
        count = int(np.prod(self._page_shape))
        idx = unpack_indices(payload, self.bits, count)
        x = dequantize(qp, jnp.asarray(idx)).reshape(self._page_shape)
        return x.astype(self._dtype)

    def _cold_nbytes(self, e: _Entry) -> int:
        return len(e.cold["k"][0]) + len(e.cold["v"][0])

    def _demote(self, e: _Entry) -> None:
        """Hot -> cold (or gone, with no cold budget).  A page that
        already has a cold blob just drops its device copy — re-demotion
        never re-quantizes, so a page degrades at most once."""
        if self.cold_bytes > 0:
            if e.cold is None:
                e.cold = {"k": self._compress(e.hot["k"]),
                          "v": self._compress(e.hot["v"])}
                self._cold_used += self._cold_nbytes(e)
            e.hot = None
            self.stats["demotions"] += 1
        else:
            e.hot = None
            del self._index[e.key]
            self.stats["hot_drops"] += 1

    def _enforce_budgets(self) -> None:
        """LRU-demote unpinned hot pages past hot_pages, then LRU-drop
        cold blobs past cold_bytes.  Pinned pages never move; a pinned
        working set larger than hot_pages overflows the budget rather
        than breaking the fetch contract."""
        n_hot = self.n_hot
        if n_hot > self.hot_pages:
            victims = sorted((e for e in self._index.values()
                              if e.hot is not None and e.refs == 0),
                             key=lambda e: e.stamp)
            for e in victims[:n_hot - self.hot_pages]:
                self._demote(e)
        if self._cold_used > self.cold_bytes:
            victims = sorted((e for e in self._index.values()
                              if e.cold is not None),
                             key=lambda e: e.stamp)
            for e in victims:
                if self._cold_used <= self.cold_bytes:
                    break
                self._cold_used -= self._cold_nbytes(e)
                e.cold = None
                self.stats["cold_drops"] += 1
                if e.hot is None:
                    del self._index[e.key]
