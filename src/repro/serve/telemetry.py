"""Process-wide but injectable telemetry: metrics + trace spans.

One registry serves every layer of the stack (frontend, scheduler,
gateway, engine/kernels):

* ``Counter`` / ``Gauge`` / ``Histogram`` — the histogram keeps fixed
  ascending bucket bounds and answers p50/p99 in closed form from the
  cumulative counts (linear interpolation inside the selected bucket);
  an ``exact=True`` mode retains the raw samples so benchmark helpers
  can reproduce ``np.percentile`` bit-for-bit.
* ``Tracer`` — span-based, Chrome-trace ("X" complete events) export
  loadable in Perfetto.  Spans carry *seconds* on whatever timeline the
  caller lives on: simulated components stamp ``EventLoop.now`` /
  ``VirtualClock`` timestamps through :meth:`Tracer.add`, wall-clock
  components use the :meth:`Telemetry.span` context manager, so
  simulated and wall runs produce structurally comparable traces.
* ``Telemetry`` — the facade components accept (``telemetry=None``
  falls back to the module-wide disabled default).  The hard contract:
  while ``enabled`` is False, instrumentation sites are skipped
  entirely — zero device→host copies, zero RNG or clock reads — so
  greedy tokens and seeded simulations stay bit-identical.

The telemetry clock is deliberately *not* the component's injected
scheduler clock: test clocks advance on every read, so borrowing them
would perturb deadline math.  Wall spans read ``time.perf_counter`` (or
whatever ``clock=`` was passed) and only when enabled.
"""
from __future__ import annotations

import json
import math
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, List, Optional, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "Span", "Tracer", "Telemetry", "default",
]


# ---------------------------------------------------------------------------
# instruments


class Counter:
    """Monotonic event count."""

    __slots__ = ("name", "labels", "n")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...] = ()):
        self.name = name
        self.labels = labels
        self.n = 0

    def inc(self, by: int = 1) -> None:
        self.n += by

    @property
    def value(self) -> int:
        return self.n


class Gauge:
    """Last-written value (pool occupancy, queue depth, EWMA estimate)."""

    __slots__ = ("name", "labels", "v")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...] = ()):
        self.name = name
        self.labels = labels
        self.v = 0.0

    def set(self, v: float) -> None:
        self.v = float(v)

    @property
    def value(self) -> float:
        return self.v


DEFAULT_BOUNDS = (1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2,
                  5e-2, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def exponential(start: float, factor: float, count: int) -> Tuple[float, ...]:
    """Geometric bucket bounds: ``start * factor**i`` for i in [0, count)."""
    return tuple(start * factor ** i for i in range(count))


class Histogram:
    """Fixed-bucket histogram with closed-form percentiles.

    Bucket ``i`` counts observations in ``(bounds[i-1], bounds[i]]``;
    an implicit overflow bucket catches everything past ``bounds[-1]``.
    ``percentile(q)`` walks the cumulative counts to the bucket holding
    the q-th observation and interpolates linearly inside it, using the
    observed min/max to tighten the open-ended edge buckets.

    ``exact=True`` additionally retains every sample and answers
    percentiles via ``np.percentile`` — benchmark helpers use this mode
    so deduplicating their percentile math cannot move row values.
    """

    __slots__ = ("name", "labels", "bounds", "counts", "count", "total",
                 "_min", "_max", "_samples")

    def __init__(self, name: str = "", bounds: Tuple[float, ...] = DEFAULT_BOUNDS,
                 labels: Tuple[Tuple[str, str], ...] = (), exact: bool = False):
        if list(bounds) != sorted(bounds):
            raise ValueError("histogram bounds must be ascending")
        self.name = name
        self.labels = labels
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)   # +1: overflow
        self.count = 0
        self.total = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._samples: Optional[List[float]] = [] if exact else None

    @classmethod
    def exact(cls, name: str = "") -> "Histogram":
        return cls(name, exact=True)

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v < self._min:
            self._min = v
        if v > self._max:
            self._max = v
        if self._samples is not None:
            self._samples.append(v)
        lo, hi = 0, len(self.bounds)
        while lo < hi:                       # first bound >= v
            mid = (lo + hi) // 2
            if self.bounds[mid] < v:
                lo = mid + 1
            else:
                hi = mid
        self.counts[lo] += 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else math.nan

    def percentile(self, q: float) -> float:
        """q in [0, 100]; NaN when empty."""
        if not self.count:
            return math.nan
        if self._samples is not None:
            import numpy as np
            return float(np.percentile(self._samples, q))
        # rank of the q-th observation (same convention as np.percentile's
        # linear interpolation, applied at bucket granularity)
        rank = q / 100.0 * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if not c:
                continue
            if cum + c >= rank or i == len(self.counts) - 1:
                lo = self.bounds[i - 1] if i > 0 else self._min
                hi = self.bounds[i] if i < len(self.bounds) else self._max
                lo = max(lo, self._min)
                hi = min(hi, self._max)
                if hi <= lo or c == 0:
                    return lo
                frac = min(max((rank - cum) / c, 0.0), 1.0)
                return lo + (hi - lo) * frac
            cum += c
        return self._max

    def p50(self) -> float:
        return self.percentile(50.0)

    def p99(self) -> float:
        return self.percentile(99.0)


# ---------------------------------------------------------------------------
# registry


def _label_key(labels: Dict[str, object]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class MetricsRegistry:
    """Create-or-get instrument store keyed by (name, labels)."""

    def __init__(self) -> None:
        self._instruments: Dict[tuple, object] = {}

    def _get(self, cls, name: str, labels: Dict[str, object], **kw):
        key = (cls.__name__, name, _label_key(labels))
        inst = self._instruments.get(key)
        if inst is None:
            inst = cls(name, labels=_label_key(labels), **kw)
            self._instruments[key] = inst
        return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, bounds: Tuple[float, ...] = DEFAULT_BOUNDS,
                  **labels) -> Histogram:
        return self._get(Histogram, name, labels, bounds=bounds)

    def instruments(self) -> List[object]:
        return list(self._instruments.values())

    # -- exporters ----------------------------------------------------------

    def to_dict(self) -> dict:
        """Flat JSON-able dump (``--metrics-json``)."""
        out: dict = {}
        for inst in self._instruments.values():
            key = inst.name
            if inst.labels:
                key += "{" + ",".join(f"{k}={v}" for k, v in inst.labels) + "}"
            if isinstance(inst, Histogram):
                out[key] = {
                    "count": inst.count, "sum": inst.total,
                    "p50": inst.p50(), "p99": inst.p99(),
                    "min": inst._min if inst.count else None,
                    "max": inst._max if inst.count else None,
                }
            else:
                out[key] = inst.value
        return out

    @staticmethod
    def _prom_name(name: str) -> str:
        return "".join(c if c.isalnum() or c == "_" else "_" for c in name)

    def prometheus_text(self) -> str:
        """Prometheus-style exposition text (the stderr metrics dump)."""
        lines: List[str] = []
        typed: set = set()
        for inst in sorted(self._instruments.values(), key=lambda i: i.name):
            pname = self._prom_name(inst.name)
            lbl = "{" + ",".join(f'{self._prom_name(k)}="{v}"'
                                 for k, v in inst.labels) + "}" \
                if inst.labels else ""
            if isinstance(inst, Counter):
                if pname not in typed:
                    lines.append(f"# TYPE {pname} counter")
                    typed.add(pname)
                lines.append(f"{pname}{lbl} {inst.n}")
            elif isinstance(inst, Gauge):
                if pname not in typed:
                    lines.append(f"# TYPE {pname} gauge")
                    typed.add(pname)
                lines.append(f"{pname}{lbl} {inst.v:.6g}")
            else:
                if pname not in typed:
                    lines.append(f"# TYPE {pname} histogram")
                    typed.add(pname)
                base = lbl[1:-1] if lbl else ""
                cum = 0
                for b, c in zip(inst.bounds, inst.counts):
                    cum += c
                    sep = "," if base else ""
                    lines.append(f'{pname}_bucket{{{base}{sep}le="{b:g}"}} '
                                 f"{cum}")
                sep = "," if base else ""
                lines.append(f'{pname}_bucket{{{base}{sep}le="+Inf"}} '
                             f"{inst.count}")
                lines.append(f"{pname}_sum{lbl} {inst.total:.6g}")
                lines.append(f"{pname}_count{lbl} {inst.count}")
        return "\n".join(lines) + ("\n" if lines else "")


# ---------------------------------------------------------------------------
# tracing


@dataclass
class Span:
    """Closed interval on some track's timeline, in seconds."""
    name: str
    t0: float
    t1: float
    track: str = "main"
    cat: str = ""
    args: dict = field(default_factory=dict)

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


class Tracer:
    """Span collector with a Chrome-trace exporter.

    Simulated components record finished intervals with :meth:`add`
    (explicit event-loop timestamps — the tracer never reads a clock on
    their behalf); wall-clock components use ``Telemetry.span``.  Tracks
    map to Chrome tids so Perfetto renders one lane per logical actor
    (scheduler, gateway, ``client 3`` …), with nesting inferred from
    interval containment.
    """

    def __init__(self) -> None:
        self.spans: List[Span] = []

    def add(self, name: str, t0: float, t1: float, *, track: str = "main",
            cat: str = "", **args) -> Span:
        sp = Span(name, float(t0), float(t1), track, cat, args)
        self.spans.append(sp)
        return sp

    def by_track(self, track: str) -> List[Span]:
        return [s for s in self.spans if s.track == track]

    def chrome_trace(self) -> dict:
        """Chrome trace-event JSON (``{"traceEvents": [...]}``).

        Timestamps are microseconds as the format requires; "M" metadata
        rows name each track's lane.
        """
        tids: Dict[str, int] = {}
        events: List[dict] = []
        for sp in self.spans:
            if sp.track not in tids:
                tid = tids[sp.track] = len(tids)
                events.append({"ph": "M", "name": "thread_name", "pid": 1,
                               "tid": tid, "args": {"name": sp.track}})
        for sp in sorted(self.spans, key=lambda s: (s.t0, -s.t1)):
            ev = {"ph": "X", "name": sp.name, "cat": sp.cat or "span",
                  "pid": 1, "tid": tids[sp.track],
                  "ts": sp.t0 * 1e6, "dur": max(sp.dur, 0.0) * 1e6}
            if sp.args:
                ev["args"] = dict(sp.args)
            events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)


# ---------------------------------------------------------------------------
# facade


class Telemetry:
    """Injectable bundle of a registry, a tracer, and a wall clock.

    ``enabled=False`` (the module default) is the no-subscriber state:
    every instrumentation site in the stack guards on ``tel.enabled``
    and is skipped outright, so the disabled path performs zero
    device→host copies and zero RNG/clock reads.  The instruments stay
    usable either way — only the *component hooks* are gated.
    """

    def __init__(self, enabled: bool = True,
                 clock: Callable[[], float] = time.perf_counter):
        self.enabled = enabled
        self.clock = clock
        self.metrics = MetricsRegistry()
        self.trace = Tracer()
        self._jit_seen: Dict[int, int] = {}

    # registry passthroughs
    def counter(self, name: str, **labels) -> Counter:
        return self.metrics.counter(name, **labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self.metrics.gauge(name, **labels)

    def histogram(self, name: str, bounds=DEFAULT_BOUNDS, **labels) -> Histogram:
        return self.metrics.histogram(name, bounds, **labels)

    @contextmanager
    def span(self, name: str, *, track: str = "main", cat: str = "",
             **args) -> Iterator[Optional[Span]]:
        """Wall-clock span; no clock read when disabled."""
        if not self.enabled:
            yield None
            return
        t0 = self.clock()
        try:
            yield None
        finally:
            self.trace.add(name, t0, self.clock(), track=track, cat=cat,
                           **args)

    # -- jit compile accounting --------------------------------------------

    def note_compiles(self, name: str, fn, shape: object = "") -> None:
        """Attribute new entries in ``fn``'s jit cache to ``shape``.

        Call after invoking the jitted ``fn``: any growth of
        ``fn._cache_size()`` since the last call is counted against the
        program-shape key the caller just ran (bucket width, buffer
        length, …).  Keyed by ``id(fn)`` so per-instance ``jax.jit``
        wrappers are tracked independently.
        """
        try:
            n = fn._cache_size()
        except Exception:
            return
        key = id(fn)
        last = self._jit_seen.get(key)
        if last is None:
            self._jit_seen[key] = n
            if n:
                self.metrics.counter(f"jit.{name}.compiles",
                                     shape=str(shape)).inc(n)
            return
        if n > last:
            self.metrics.counter(f"jit.{name}.compiles",
                                 shape=str(shape)).inc(n - last)
        self._jit_seen[key] = n

    def compile_count(self, prefix: str = "") -> int:
        """Total jit compiles recorded (optionally for one ``jit.<prefix>``)."""
        want = f"jit.{prefix}" if prefix else "jit."
        return sum(c.n for c in self.metrics.instruments()
                   if isinstance(c, Counter) and c.name.startswith(want)
                   and c.name.endswith(".compiles"))


_DEFAULT = Telemetry(enabled=False)


def default() -> Telemetry:
    """The process-wide registry (disabled until someone enables it)."""
    return _DEFAULT
