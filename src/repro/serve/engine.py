"""Serving engine: request queue -> bucketed prefill -> slot-pool decode.

Two execution paths share one `generate` API for the Remote-NN role:

  * equal-length fast path — requests whose prompts share one length are
    grouped into a single prefill and decoded as one
    `jax.lax.while_loop` device program (sampling, EOS/done masking and
    per-request length limits in-graph, cache donated on TPU), issuing
    O(1) host transfers per call.  Bit-compatible with the PR-1 engine.
  * continuous batching — mixed-length queues route through
    `repro.serve.scheduler.ContinuousScheduler`: prompts are right-padded
    into length buckets (pad keys masked out of attention), prefilled
    per bucket, and injected into a fixed-width decode slot pool whose
    chunked while_loop segments evict finished requests and admit queued
    ones without recompiling.  Greedy outputs are identical to decoding
    each request alone.

Per-request temperature (0 => greedy) and EOS ids are honoured in-graph
on both paths; architectures the scheduler cannot serve (recurrent state,
MoE, absolute positions) fall back to equal-length grouping.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import backbone as bb
from repro.serve.scheduler import (
    ContinuousScheduler,
    SchedulerConfig,
    sample_tokens,
    supports_continuous_batching,
)


@dataclasses.dataclass
class Request:
    tokens: np.ndarray                 # (T,) prompt
    max_new_tokens: int = 16
    eos_id: int = -1                   # -1: never stops early
    temperature: float = 0.0           # 0 => greedy
    extras: Optional[dict] = None      # patches / frames for vlm / audio
    deadline_s: Optional[float] = None  # wall seconds from submit; past it
                                        # the scheduler evicts the request
                                        # between chunks (partial tokens,
                                        # Completion.timed_out=True)


@dataclasses.dataclass
class Completion:
    tokens: np.ndarray
    steps: int
    timed_out: bool = False            # deadline-evicted mid-decode: tokens
                                       # hold whatever was generated in time


def _decode_loop(cfg: ArchConfig, params, logits0, cache, cache_len, key,
                 eos_ids, max_lens, max_new, temps, *, buf_len: int,
                 greedy: bool):
    """Whole decode phase as one device program.

    Samples the first token from the prefill logits, then runs a
    while_loop of decode_step + sample + done-masking.  max_new is a
    traced loop bound (no recompile across request budgets); temps is a
    per-request vector (rows with temp <= 0 take argmax in-graph); only
    the batch/cache shapes and the all-greedy flag shape the program.
    Returns (token buffer (B, buf_len), per-request lengths, steps
    executed).
    """
    B = logits0.shape[0]

    def sample(logits, key):
        if greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), key
        key, sub = jax.random.split(key)
        return sample_tokens(logits, temps, sub), key

    tok0, key = sample(logits0, key)
    buf = jnp.zeros((B, buf_len), jnp.int32).at[:, 0].set(tok0)
    lengths = jnp.ones((B,), jnp.int32)
    done = (tok0 == eos_ids) | (lengths >= max_lens)
    state = (jnp.zeros((), jnp.int32), buf, lengths, done, tok0[:, None],
             cache, jnp.asarray(cache_len, jnp.int32), key)

    def cond(state):
        step, _, _, done, _, _, _, _ = state
        return (step < max_new - 1) & ~jnp.all(done)

    def body(state):
        step, buf, lengths, done, tok, cache, cl, key = state
        logits, cache = bb.decode_step(cfg, params, tok, cache, cl)
        t, key = sample(logits, key)
        active = ~done
        pos = jnp.where(active, lengths, buf_len)      # OOB rows -> dropped
        buf = buf.at[jnp.arange(B), pos].set(t, mode="drop")
        lengths = lengths + active.astype(jnp.int32)
        done = done | (active & ((t == eos_ids) | (lengths >= max_lens)))
        return (step + 1, buf, lengths, done, t[:, None], cache, cl + 1, key)

    step, buf, lengths, done, _, _, _, _ = jax.lax.while_loop(cond, body, state)
    return buf, lengths, step + 1


def _stack_extras(requests: list[Request]) -> dict:
    """Validated extras batch: every request must carry the same keys
    (a mixed batch would silently drop or misalign modality inputs)."""
    key_sets = {frozenset((r.extras or {}).keys()) for r in requests}
    if len(key_sets) > 1:
        raise ValueError(
            "all requests in a batch must carry the same extras keys; got "
            + " vs ".join(str(sorted(s)) for s in key_sets))
    ex = requests[0].extras or {}
    return {k: jnp.asarray(np.stack([r.extras[k] for r in requests]))
            for k in ex}


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, *, max_len: int = 256,
                 seed: int = 0, scheduler: Optional[SchedulerConfig] = None,
                 mesh=None, telemetry=None):
        from repro.serve import telemetry as _telemetry
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.tel = telemetry if telemetry is not None else _telemetry.default()
        self.mesh = mesh            # scheduler path only: slot pool shards
                                    # over the data axes, params go tensor-
                                    # parallel (launch.partition)
        if mesh is not None:
            assert supports_continuous_batching(cfg), \
                f"{cfg.name}: sharded serving runs through the continuous " \
                "scheduler, which this architecture gates out — a meshed " \
                "engine would silently serve unsharded on one device"
        self._seed = seed
        self._key = jax.random.PRNGKey(seed)
        self._sched_cfg = scheduler or SchedulerConfig()
        self._sched: Optional[ContinuousScheduler] = None
        # cache is donated where the backend supports it (TPU): the
        # prefill cache buffers are reused in place by the loop instead
        # of being copied per step
        donate = (2,) if jax.default_backend() == "tpu" else ()
        self._loop = jax.jit(partial(_decode_loop, cfg),
                             static_argnames=("buf_len", "greedy"),
                             donate_argnums=donate)
        # jit'd prefill (compiles once per prompt length): the op-by-op
        # eager prefill used to dominate the equal-length path's wall
        # clock, benching it below the scheduler on the same requests
        self._prefill = jax.jit(partial(bb.prefill, cfg),
                                static_argnames=("max_len",))

    @property
    def scheduler(self) -> ContinuousScheduler:
        """The lazily built continuous-batching scheduler (shared pool and
        compiled programs across generate calls)."""
        if self._sched is None:
            self._sched = ContinuousScheduler(
                self.cfg, self.params, sched=self._sched_cfg,
                max_len=self.max_len, seed=self._seed + 1, mesh=self.mesh,
                telemetry=self.tel)
        return self._sched

    def generate(self, requests: list[Request]) -> list[Completion]:
        """One Completion per request, in submission order.  Equal-length
        prompts take the single-batch fast path (unless a mesh is set —
        sharded serving always goes through the scheduler); mixed lengths
        run through the continuous-batching scheduler (or equal-length
        grouping when the architecture rules the scheduler out)."""
        assert requests, "empty batch"
        lens = {len(r.tokens) for r in requests}
        schedulable = (supports_continuous_batching(self.cfg)
                       and all(r.extras is None for r in requests))
        deadlines = any(r.deadline_s is not None for r in requests)
        if deadlines and not schedulable:
            raise ValueError(
                "per-request deadlines are honored by the continuous "
                "scheduler only; this architecture (or extras-carrying "
                "batch) routes through the equal-length path, which cannot "
                "evict mid-decode")
        # with a mesh, everything routes through the (sharded) scheduler:
        # the fast path is single-device, and silently dropping the mesh
        # would un-shard params a caller sharded because they must be
        if self.mesh is not None and not schedulable:
            raise ValueError(
                "sharded serving cannot take requests with extras — they "
                "route through the single-device fast path, dropping the "
                "mesh")
        if len(lens) == 1 and self.mesh is None and not deadlines:
            return self._generate_equal(requests)
        if schedulable:
            sched = self.scheduler
            rids = [sched.submit(r) for r in requests]
            outs = sched.run()
            return [outs[rid] for rid in rids]
        # fallback: one fast-path call per prompt-length group
        by_len: dict[int, list[int]] = {}
        for i, r in enumerate(requests):
            by_len.setdefault(len(r.tokens), []).append(i)
        out: list[Optional[Completion]] = [None] * len(requests)
        for idxs in by_len.values():
            for i, c in zip(idxs, self._generate_equal(
                    [requests[i] for i in idxs])):
                out[i] = c
        return out

    def _generate_equal(self, requests: list[Request]) -> list[Completion]:
        """Single-prefill path: all prompts share one length."""
        T = len(requests[0].tokens)
        assert all(len(r.tokens) == T for r in requests)
        batch = {"tokens": jnp.asarray(
            np.stack([r.tokens for r in requests]), jnp.int32)}
        batch.update(_stack_extras(requests))

        logits, cache, total_T = self._prefill(self.params, batch,
                                               max_len=self.max_len)
        if self.tel.enabled:
            self.tel.note_compiles("engine.prefill", self._prefill,
                                   shape=f"T{T}xB{len(requests)}")
        total_T = int(total_T)
        max_new = max(r.max_new_tokens for r in requests)
        assert max_new <= self.max_len, \
            f"max_new_tokens {max_new} exceeds engine max_len {self.max_len}"
        if self.cfg.sliding_window == 0:
            # full-attention caches are not rings: a wrap would overwrite
            # context the model still attends to, silently (SWA archs wrap
            # by design — the window is the attention span)
            assert total_T + max_new <= self.max_len, \
                f"context {total_T} + max_new_tokens {max_new} exceeds " \
                f"engine max_len {self.max_len}: decode would ring-wrap " \
                "over live context"
        temps = np.asarray([r.temperature for r in requests], np.float32)
        self._key, sub = jax.random.split(self._key)
        eos_ids = jnp.asarray([r.eos_id for r in requests], jnp.int32)
        max_lens = jnp.asarray([r.max_new_tokens for r in requests], jnp.int32)

        buf, lengths, steps = self._loop(
            self.params, logits, cache, total_T, sub, eos_ids, max_lens,
            jnp.int32(max_new), jnp.asarray(temps),
            buf_len=self.max_len, greedy=bool(np.all(temps <= 0.0)))
        if self.tel.enabled:
            self.tel.note_compiles(
                "engine.decode_loop", self._loop,
                shape=f"buf{self.max_len}_greedy{bool(np.all(temps <= 0.0))}")
        # the single device->host transfer of the decode phase
        buf, lengths, steps = (np.asarray(buf), np.asarray(lengths),
                               int(steps))
        return [Completion(buf[b, :lengths[b]].astype(np.int32), steps)
                for b in range(len(requests))]
