"""Batched serving engine: request queue -> prefill -> stepwise decode.

A deliberately small, dependency-free engine for the Remote-NN role:
requests with equal-length prompts are grouped into one prefill; decoding
proceeds in lockstep with per-request stop handling (static batch — the
dry-run decode shapes correspond to one engine step).  Greedy or
temperature sampling.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import backbone as bb


@dataclasses.dataclass
class Request:
    tokens: np.ndarray                 # (T,) prompt
    max_new_tokens: int = 16
    eos_id: int = -1                   # -1: never stops early
    temperature: float = 0.0           # 0 => greedy
    extras: Optional[dict] = None      # patches / frames for vlm / audio


@dataclasses.dataclass
class Completion:
    tokens: np.ndarray
    steps: int


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, *, max_len: int = 256,
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(
            lambda p, t, c, n: bb.decode_step(cfg, p, t, c, n))

    def _sample(self, logits, temperature: float):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        self._key, sub = jax.random.split(self._key)
        return jax.random.categorical(sub, logits / temperature, axis=-1)

    def generate(self, requests: list[Request]) -> list[Completion]:
        """All prompts must share one length (the engine's batch grouping
        unit); returns one Completion per request."""
        assert requests, "empty batch"
        T = len(requests[0].tokens)
        assert all(len(r.tokens) == T for r in requests), \
            "group requests by prompt length"
        B = len(requests)
        batch = {"tokens": jnp.asarray(
            np.stack([r.tokens for r in requests]), jnp.int32)}
        ex = requests[0].extras or {}
        for k in ex:
            batch[k] = jnp.asarray(np.stack([r.extras[k] for r in requests]))

        logits, cache, total_T = bb.prefill(
            self.cfg, self.params, batch, max_len=self.max_len)
        max_new = max(r.max_new_tokens for r in requests)
        temps = requests[0].temperature
        tok = self._sample(logits, temps)[:, None].astype(jnp.int32)

        out = [[int(tok[b, 0])] for b in range(B)]
        done = np.zeros(B, bool)
        cl = total_T
        steps = 1
        for _ in range(max_new - 1):
            if done.all():
                break
            logits, cache = self._decode(self.params, tok, cache, cl)
            tok = self._sample(logits, temps)[:, None].astype(jnp.int32)
            cl += 1
            steps += 1
            t_np = np.asarray(tok[:, 0])
            for b, r in enumerate(requests):
                if done[b]:
                    continue
                out[b].append(int(t_np[b]))
                if t_np[b] == r.eos_id or len(out[b]) >= r.max_new_tokens:
                    done[b] = True
        return [Completion(np.asarray(o, np.int32), steps) for o in out]
