"""Batched serving engine: request queue -> prefill -> sync-free decode.

A deliberately small, dependency-free engine for the Remote-NN role:
requests with equal-length prompts are grouped into one prefill; decoding
runs entirely on device as a single `jax.lax.while_loop` — sampling,
EOS/done masking, and per-request length limits are all in-graph, and the
KV cache is donated to the loop (on TPU).  One `generate` call therefore issues
O(1) host transfers (prefill dispatch, loop dispatch, one final copy of
the token buffer) instead of O(max_new_tokens) round-trips.  Greedy or
temperature sampling.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import backbone as bb


@dataclasses.dataclass
class Request:
    tokens: np.ndarray                 # (T,) prompt
    max_new_tokens: int = 16
    eos_id: int = -1                   # -1: never stops early
    temperature: float = 0.0           # 0 => greedy
    extras: Optional[dict] = None      # patches / frames for vlm / audio


@dataclasses.dataclass
class Completion:
    tokens: np.ndarray
    steps: int


def _decode_loop(cfg: ArchConfig, params, logits0, cache, cache_len, key,
                 eos_ids, max_lens, max_new, temperature, *, buf_len: int,
                 greedy: bool):
    """Whole decode phase as one device program.

    Samples the first token from the prefill logits, then runs a
    while_loop of decode_step + sample + done-masking.  max_new is a
    traced loop bound (no recompile across request budgets); only the
    batch/cache shapes and the greedy flag shape the program.  Returns
    (token buffer (B, buf_len), per-request lengths, steps executed).
    """
    B = logits0.shape[0]

    def sample(logits, key):
        if greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), key
        key, sub = jax.random.split(key)
        t = jax.random.categorical(sub, logits / temperature, axis=-1)
        return t.astype(jnp.int32), key

    tok0, key = sample(logits0, key)
    buf = jnp.zeros((B, buf_len), jnp.int32).at[:, 0].set(tok0)
    lengths = jnp.ones((B,), jnp.int32)
    done = (tok0 == eos_ids) | (lengths >= max_lens)
    state = (jnp.zeros((), jnp.int32), buf, lengths, done, tok0[:, None],
             cache, jnp.asarray(cache_len, jnp.int32), key)

    def cond(state):
        step, _, _, done, _, _, _, _ = state
        return (step < max_new - 1) & ~jnp.all(done)

    def body(state):
        step, buf, lengths, done, tok, cache, cl, key = state
        logits, cache = bb.decode_step(cfg, params, tok, cache, cl)
        t, key = sample(logits, key)
        active = ~done
        pos = jnp.where(active, lengths, buf_len)      # OOB rows -> dropped
        buf = buf.at[jnp.arange(B), pos].set(t, mode="drop")
        lengths = lengths + active.astype(jnp.int32)
        done = done | (active & ((t == eos_ids) | (lengths >= max_lens)))
        return (step + 1, buf, lengths, done, t[:, None], cache, cl + 1, key)

    step, buf, lengths, done, _, _, _, _ = jax.lax.while_loop(cond, body, state)
    return buf, lengths, step + 1


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, *, max_len: int = 256,
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._key = jax.random.PRNGKey(seed)
        # cache is donated where the backend supports it (TPU): the
        # prefill cache buffers are reused in place by the loop instead
        # of being copied per step
        donate = (2,) if jax.default_backend() == "tpu" else ()
        self._loop = jax.jit(partial(_decode_loop, cfg),
                             static_argnames=("buf_len", "greedy"),
                             donate_argnums=donate)

    def generate(self, requests: list[Request]) -> list[Completion]:
        """All prompts must share one length (the engine's batch grouping
        unit); returns one Completion per request."""
        assert requests, "empty batch"
        T = len(requests[0].tokens)
        assert all(len(r.tokens) == T for r in requests), \
            "group requests by prompt length"
        batch = {"tokens": jnp.asarray(
            np.stack([r.tokens for r in requests]), jnp.int32)}
        ex = requests[0].extras or {}
        for k in ex:
            batch[k] = jnp.asarray(np.stack([r.extras[k] for r in requests]))

        logits, cache, total_T = bb.prefill(
            self.cfg, self.params, batch, max_len=self.max_len)
        max_new = max(r.max_new_tokens for r in requests)
        assert max_new <= self.max_len, \
            f"max_new_tokens {max_new} exceeds engine max_len {self.max_len}"
        temp = requests[0].temperature
        self._key, sub = jax.random.split(self._key)
        eos_ids = jnp.asarray([r.eos_id for r in requests], jnp.int32)
        max_lens = jnp.asarray([r.max_new_tokens for r in requests], jnp.int32)

        buf, lengths, steps = self._loop(
            self.params, logits, cache, total_T, sub, eos_ids, max_lens,
            jnp.int32(max_new), jnp.float32(max(temp, 1e-6)),
            buf_len=self.max_len, greedy=temp <= 0.0)
        # the single device->host transfer of the decode phase
        buf, lengths, steps = (np.asarray(buf), np.asarray(lengths),
                               int(steps))
        return [Completion(buf[b, :lengths[b]].astype(np.int32), steps)
                for b in range(len(requests))]
