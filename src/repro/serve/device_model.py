"""Embedded-device cost model (paper §6-§7 hardware).

Latency and energy for the STM32F746-class local device:
  - compute: MACs / (f_cpu * MACs-per-cycle)   (CMSIS-NN int8 ~1 MAC/cycle)
  - radio:   bytes * 8 / link_bps              (ESP-WROOM WiFi, UDP 6 Mbps,
                                                narrowband option 270 kbps)
  - energy:  P_cpu * t_compute + P_tx * t_tx
Constants (documented, order-of-magnitude from the STM32F746 and
ESP-WROOM-02D datasheets):
  P_cpu ~ 0.33 W (100 mA @ 3.3 V active), P_tx ~ 0.56 W (170 mA @ 3.3 V).
The server side (A6000 role) uses a 5 TMAC/s effective throughput; it is
never the bottleneck, matching the paper.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    cpu_hz: float = 216e6
    macs_per_cycle: float = 1.0
    link_bps: float = 6e6
    p_cpu_w: float = 0.33
    p_tx_w: float = 0.56
    server_macs_per_s: float = 5e12
    server_overhead_s: float = 1e-3      # decompress + dispatch

    def compute_time(self, macs: float) -> float:
        return macs / (self.cpu_hz * self.macs_per_cycle)

    def tx_time(self, payload_bytes: float) -> float:
        return payload_bytes * 8.0 / self.link_bps

    def server_time(self, macs: float) -> float:
        return self.server_overhead_s + macs / self.server_macs_per_s

    def energy(self, local_macs: float, payload_bytes: float) -> float:
        return (self.p_cpu_w * self.compute_time(local_macs)
                + self.p_tx_w * self.tx_time(payload_bytes))


@dataclasses.dataclass
class InferenceCost:
    local_compute_s: float
    tx_s: float
    server_s: float
    payload_bytes: float
    local_macs: float
    remote_macs: float

    @property
    def end_to_end_s(self) -> float:
        return self.local_compute_s + self.tx_s + self.server_s

    @property
    def as_dict(self) -> dict:
        return {
            "local_compute_ms": self.local_compute_s * 1e3,
            "tx_ms": self.tx_s * 1e3,
            "server_ms": self.server_s * 1e3,
            "end_to_end_ms": self.end_to_end_s * 1e3,
            "payload_bytes": self.payload_bytes,
            "local_macs": self.local_macs,
            "remote_macs": self.remote_macs,
        }


def mcu_memory_model(local_param_count: int, activation_floats: int,
                     *, int8: bool = True) -> dict:
    """SRAM/flash estimate for the local model (TFLite-Micro style):
    weights in flash (int8), activations in SRAM (int8 ping-pong)."""
    w_bytes = local_param_count * (1 if int8 else 4)
    a_bytes = activation_floats * (1 if int8 else 4)
    return {"flash_bytes": w_bytes, "sram_bytes": a_bytes}
