"""Per-client adaptive rate control (the DynO-style dynamic split knob).

When a client's measured end-to-end latency drifts above its SLO the
controller walks down a *rate ladder* — first fewer quantization bits,
then a smaller fraction of offloaded channels — and walks back up once
the channel recovers.  Level 0 is the static configuration: the full
learned codebook and every remote channel, bit-identical to the
single-image offload path (`run_offload_inference`), so a fleet with no
SLO reproduces today's deployment exactly.

Dropping channels exploits the same property the split itself does: the
disorder loss orders channels by importance, so the transmitted prefix
keeps the most informative features and the gateway zero-fills the tail.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.compress.quantize import quantization_bits


@dataclasses.dataclass(frozen=True)
class RateProfile:
    bits: int                # quantization bits (codebook of 2**bits centers)
    keep_frac: float = 1.0   # fraction of offloaded channels transmitted

    @property
    def key(self) -> tuple:
        return (self.bits, self.keep_frac)


def default_ladder(n_centers: int) -> tuple[RateProfile, ...]:
    """Static profile first, then progressively cheaper payloads."""
    full = quantization_bits(n_centers)
    ladder = [RateProfile(bits=full, keep_frac=1.0)]
    for bits, frac in ((full - 1, 1.0), (full - 1, 0.5),
                       (max(1, full - 2), 0.5), (max(1, full - 2), 0.25)):
        prof = RateProfile(bits=max(1, bits), keep_frac=frac)
        if prof != ladder[-1]:
            ladder.append(prof)
    return tuple(ladder)


# Degradation floor of the channel-masking ladder: no payload channel
# survives.  The gateway zero-fills the whole offloaded feature map and
# still serves Remote NN + combine — a lost or corrupted payload costs
# accuracy, not a round trip (the SemanticNN posture).
ERASED = RateProfile(bits=1, keep_frac=0.0)


def keep_channels(prof: RateProfile, n_remote: int, full_bits: int) -> int:
    """Transmitted-channel count of a rate profile: the full set at the
    static profile, an importance-prefix otherwise, and zero at the
    `ERASED` floor (the gateway zero-fills everything past this count)."""
    if prof.keep_frac <= 0.0:
        return 0
    if prof.bits >= full_bits and prof.keep_frac >= 1.0:
        return n_remote
    return max(1, int(round(prof.keep_frac * n_remote)))


def subset_centers(centers: np.ndarray, bits: int) -> np.ndarray:
    """Codebook of a reduced-bit profile: 2**bits centers spread evenly
    over the *sorted* learned codebook.  A bit width covering the whole
    codebook returns it unchanged, keeping indices compatible with the
    fused offload kernel's full-codebook output."""
    centers = np.asarray(centers, np.float32)
    m = 1 << bits
    if m >= centers.shape[0]:
        return centers
    order = np.argsort(centers, kind="stable")
    pick = np.round(np.linspace(0, centers.shape[0] - 1, m)).astype(int)
    return centers[order][pick]


def requantize(values: np.ndarray, centers: np.ndarray) -> np.ndarray:
    """Nearest-center indices, ties to the lowest index — the numpy
    mirror of ``kernels.common.nearest_center_scan`` for host-side
    re-quantization at reduced bit widths."""
    d2 = (values[..., None].astype(np.float32)
          - centers.astype(np.float32)) ** 2
    return np.argmin(d2, axis=-1).astype(np.int32)


class RateController:
    """EWMA latency tracker walking the rate ladder against an SLO.

    ``slo_s=None`` disables control: the profile is pinned to level 0
    (the static configuration).  Recovery uses a hysteresis band below
    the SLO so the level doesn't oscillate across the threshold."""

    def __init__(self, ladder: tuple[RateProfile, ...],
                 slo_s: "float | None" = None, *, ewma: float = 0.4,
                 recover: float = 0.7):
        assert ladder, "empty rate ladder"
        self.ladder = tuple(ladder)
        self.slo_s = slo_s
        self.ewma = ewma
        self.recover = recover
        self.level = 0
        self._lat: "float | None" = None

    def profile(self) -> RateProfile:
        return self.ladder[self.level]

    def observe(self, e2e_s: float) -> None:
        if self.slo_s is None:
            return
        self._lat = (e2e_s if self._lat is None
                     else (1.0 - self.ewma) * self._lat + self.ewma * e2e_s)
        if self._lat > self.slo_s:
            self.level = min(self.level + 1, len(self.ladder) - 1)
        elif self._lat < self.recover * self.slo_s:
            self.level = max(self.level - 1, 0)
