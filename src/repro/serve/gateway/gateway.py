"""Event-driven multi-client offload gateway.

Closes the device<->cloud loop the per-image offload runtime leaves open:
N simulated weak devices (`Fleet`) push LZW-compressed feature payloads
over lossy rate-limited links (`Channel`) into a gateway that batches
arrivals into fixed-width Remote-NN inference calls and returns combined
logits with per-request end-to-end latency and device-energy accounting.

Time is discrete-event simulated on the serving stack's shared
`repro.serve.event_loop.EventLoop` (a (time, prio, seq) heap; prio
breaks same-instant ties toward the earliest deadline and seq keeps the
rest FIFO, so runs are deterministic — the same loop class drives the
streaming frontend's overload benches, so gateway arrivals and decode
rounds share one clock discipline), while the Remote-NN logits are
*actually computed*: arriving payloads are LZW-decoded, batch-bit-
unpacked, dequantized and run through a jit'd `remote_forward` over a
fixed-width feature slot pool — the continuous scheduler's admit/evict
discipline applied to feature batches, with one compiled program per
pool shape.  Requests admit into free `SlotPool` slots when a batch
launches and release them when it completes; arrivals beyond the pool
width queue for the next launch.

Failure posture (`repro.serve.faults` wires the faults in): every layer
responds instead of hanging, stepping down a degradation ladder —

  * served    — payload decoded, Remote NN + combine (the clean path);
  * degraded  — the payload arrived corrupted (`PayloadCorruptionError`
    or a framing-length mismatch): the gateway zero-fills every
    offloaded channel (`control.ERASED`, the keep-prefix masking taken
    to its floor) and still serves Remote NN + combine — accuracy pays,
    not a round trip;
  * shed      — the payload arrived, but its deadline passed before (or
    lapses at) batch admission: the gateway drops it and the device uses
    its Local-NN logits;
  * rejected  — the payload arrived but the gateway's admission queue
    was already at ``GatewayConfig.max_queue``: overload is refused at
    the door instead of buffered without bound, and the device falls
    back to its Local-NN logits immediately (with an unbounded queue —
    the default — this rung never fires and every run is bit-identical
    to the pre-admission-control gateway);
  * fallback  — the radio gave up (retry budget or deadline exhausted on
    a dark link): the device serves its own Local-NN logits, bit-
    identical to the standalone local path, the moment it stops retrying.

Requests carrying deadlines admit earliest-deadline-first; with none set
admission is FIFO and every code path is bit-identical to the fault-free
gateway.  With no SLO set every client stays on the static rate profile
and the gateway's logits are bit-identical to `run_offload_inference` on
each request's image alone (tested); with an SLO, per-client
`RateController`s trade quantization bits / offloaded-channel fraction
against the measured latency.
"""
from __future__ import annotations

import dataclasses
import math
import time
from functools import partial

import jax.numpy as jnp
import numpy as np

from repro.compress.lzw import (
    PayloadCorruptionError, lzw_decode, packed_nbytes, unpack_indices_batch,
)
from repro.configs.agilenn_cifar import AgileNNConfig
from repro.core.agile import remote_forward_jit
from repro.serve.device_model import DeviceModel
from repro.serve.event_loop import EventLoop
from repro.serve.gateway.fleet import DeviceClient, Fleet, Payload
from repro.serve.scheduler import SlotPool
from repro.serve.telemetry import exponential

_MS_BOUNDS = exponential(0.25, 2.0, 16)    # 0.25 ms .. ~8.2 s


@dataclasses.dataclass(frozen=True)
class GatewayConfig:
    batch_width: int = 8        # Remote-NN feature slot pool width
    batch_window_s: float = 2e-3  # idle gateway waits this long after an
                                  # arrival for the pool to fill
    max_queue: "int | None" = None  # admission-queue bound: an arrival
                                    # finding this many payloads already
                                    # queued is *rejected* (typed ladder
                                    # rung above shed) and the device
                                    # falls back to Local-NN immediately.
                                    # None (default) = unbounded, bit-
                                    # identical to the pre-bound gateway

    def __post_init__(self):
        if self.batch_width < 1:
            raise ValueError(f"GatewayConfig.batch_width must be >= 1 "
                             f"(got {self.batch_width!r})")
        if self.batch_window_s < 0:
            raise ValueError(f"GatewayConfig.batch_window_s must be >= 0 "
                             f"(got {self.batch_window_s!r})")
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(f"GatewayConfig.max_queue must be >= 1 or "
                             f"None (got {self.max_queue!r})")


@dataclasses.dataclass
class RequestTrace:
    client: int
    req: int
    channel: str
    bits: int
    keep: int                  # transmitted remote channels
    payload_bytes: int
    attempts: int
    t_born: float              # inference requested on-device
    t_sent: float              # local compute done, radio starts
    t_arrive: float            # payload lands at the gateway
    t_serve: float             # admitted into a Remote-NN batch
    t_done: float              # combined logits back at the device
    e2e_s: float
    energy_j: float
    logits: np.ndarray
    pred: int
    label: int
    status: str = "served"     # served | degraded | shed | rejected |
                               # fallback
    deadline_missed: bool = False


@dataclasses.dataclass
class GatewayReport:
    traces: list[RequestTrace]
    wall_s: float
    sim_s: float
    n_clients: int

    def e2e_ms(self) -> np.ndarray:
        return np.asarray([t.e2e_s for t in self.traces]) * 1e3

    def latency_percentile_ms(self, q: float) -> float:
        return float(np.percentile(self.e2e_ms(), q))

    @property
    def clients_per_s(self) -> float:
        """Sustained client inferences per *wall* second — the throughput
        of the real pipeline (payload codecs, event loop, batched
        Remote-NN calls), not of the simulated clock."""
        return len(self.traces) / self.wall_s

    @property
    def device_energy_mj(self) -> float:
        return float(np.mean([t.energy_j for t in self.traces])) * 1e3

    def status_rate(self, *statuses: str) -> float:
        return float(np.mean([t.status in statuses for t in self.traces]))

    @property
    def fallback_rate(self) -> float:
        """Fraction of requests resolved by Local-NN logits alone (the
        radio gave up, or the gateway shed a missed deadline)."""
        return self.status_rate("fallback", "shed")

    @property
    def degraded_rate(self) -> float:
        """Fraction served with zero-filled (erased) payload channels."""
        return self.status_rate("degraded")

    @property
    def rejected_rate(self) -> float:
        """Fraction refused at the gateway's admission bound (the
        overload rung: the queue was full when the payload landed)."""
        return self.status_rate("rejected")

    @property
    def deadline_miss_rate(self) -> float:
        return float(np.mean([t.deadline_missed for t in self.traces]))

    def summary(self) -> dict:
        by_channel: dict[str, list[float]] = {}
        for t in self.traces:
            by_channel.setdefault(t.channel, []).append(t.e2e_s * 1e3)
        return {
            "clients": self.n_clients,
            "requests": len(self.traces),
            "e2e_p50_ms": self.latency_percentile_ms(50),
            "e2e_p99_ms": self.latency_percentile_ms(99),
            "clients_per_s": self.clients_per_s,
            "device_energy_mj": self.device_energy_mj,
            "payload_bytes_mean": float(np.mean(
                [t.payload_bytes for t in self.traces])),
            "attempts_mean": float(np.mean(
                [t.attempts for t in self.traces])),
            "bits_mean": float(np.mean([t.bits for t in self.traces])),
            "accuracy": float(np.mean(
                [t.pred == t.label for t in self.traces])),
            "fallback_rate": self.fallback_rate,
            "degraded_rate": self.degraded_rate,
            "rejected_rate": self.rejected_rate,
            "deadline_miss_rate": self.deadline_miss_rate,
            "sim_s": self.sim_s,
            "p50_ms_by_channel": {k: float(np.percentile(v, 50))
                                  for k, v in sorted(by_channel.items())},
        }


@dataclasses.dataclass
class _InFlight:
    payload: Payload
    client: DeviceClient
    t_born: float
    t_start: float
    t_sent: float
    t_arrive: float
    attempts: int
    energy_j: float
    t_serve: float = 0.0       # stamped when the batch launches
    slot: int = -1             # pool slot (= Remote-NN batch row) occupied
    deadline: float = math.inf  # absolute; heap/admission priority
    status: str = "served"     # downgraded to "degraded" on erasure
    delivery: object = None    # the radio's Delivery (attempt windows for
                               # telemetry hop spans)


class OffloadGateway:
    def __init__(self, cfg: AgileNNConfig, params, fleet: Fleet,
                 gw: "GatewayConfig | None" = None, *,
                 server: "DeviceModel | None" = None, faults=None,
                 telemetry=None):
        from repro.serve import telemetry as _telemetry
        assert fleet.cfg is cfg or fleet.cfg == cfg
        self.cfg = cfg
        self.params = params
        self.fleet = fleet
        self.gw = gw or GatewayConfig()
        self.server = server or DeviceModel()
        self.faults = faults               # repro.serve.faults.FaultInjector
        self.tel = telemetry if telemetry is not None \
            else _telemetry.default()
        self._slots = SlotPool(self.gw.batch_width)
        # one compiled program per pool shape, cached module-wide
        self._remote = partial(remote_forward_jit,
                               temperature=cfg.agile.alpha_temperature)

    # ------------------------------------------------------ remote batch --
    def _batch_logits(self, batch: list[_InFlight]) -> np.ndarray:
        """Decode payloads -> dequantize -> one fixed-width Remote-NN +
        combine call.  Rows are grouped by radio framing so the bit
        unpack runs vectorized per group; channels beyond a payload's
        importance prefix stay zero.  A payload that fails to decode
        (corruption) keeps its WHOLE row zero — the `control.ERASED`
        floor of the masking ladder — and is marked degraded; the call
        still serves it."""
        t_codec = self.tel.clock() if self.tel.enabled else 0.0
        W = self.gw.batch_width
        fh, Cr = self.fleet.feat_hw, self.fleet.n_remote
        deq = np.zeros((W, fh, fh, Cr), np.float32)
        ll = np.zeros((W, self.fleet.local_logits.shape[1]), np.float32)
        groups: dict[tuple, list[_InFlight]] = {}
        for item in batch:
            p = item.payload
            ll[item.slot] = self.fleet.local_logits[item.client.row0 + p.req]
            groups.setdefault((p.bits, p.keep, p.count), []).append(item)
        for (bits, keep, count), members in groups.items():
            ok, packed = [], []
            expect = packed_nbytes(bits, count)
            for it in members:
                try:
                    data = lzw_decode(it.payload.codes)
                except PayloadCorruptionError:
                    it.status = "degraded"
                    continue
                if len(data) != expect:    # framing mismatch: erased too
                    it.status = "degraded"
                    continue
                ok.append(it)
                packed.append(data)
            if not ok:
                continue
            idx = unpack_indices_batch(packed, bits, count)
            vals = self.fleet.centers_for(bits)[idx]
            rows = [it.slot for it in ok]
            deq[rows, :, :, :keep] = vals.reshape(-1, fh, fh, keep)
        if self.tel.enabled:
            # wall cost of the gateway-side codec (LZW decode + unpack +
            # dequantize) — the device-side encode is simulated time,
            # folded into the device_compute span
            self.tel.histogram("gateway.codec_ms", bounds=_MS_BOUNDS) \
                .observe((self.tel.clock() - t_codec) * 1e3)
        out = self._remote(self.params, jnp.asarray(deq), jnp.asarray(ll))
        return np.asarray(out)

    # -------------------------------------------------------- telemetry --
    def _note_request(self, item: _InFlight, t_done: float, status: str,
                      *, remote: bool) -> None:
        """Emit one resolved request's hop spans (simulated timestamps —
        no clock reads) and counters.  The spans tile the request's e2e
        window: device queue/compute, each radio attempt with its
        backoff gap, uplink propagation, gateway queue wait, the remote
        slot-pool batch, and the response leg."""
        tel = self.tel
        if not tel.enabled:
            return
        p = item.payload
        track = f"c{item.client.index} r{p.req}"
        add = tel.trace.add
        add("request", item.t_born, t_done, track=track, cat="gateway",
            status=status, client=item.client.index, req=p.req,
            channel=item.client.spec.channel.name)
        if item.t_start > item.t_born:
            add("device_queue", item.t_born, item.t_start, track=track,
                cat="gateway")
        add("device_compute", item.t_start, item.t_sent, track=track,
            cat="gateway", payload_bytes=p.nbytes, bits=p.bits, keep=p.keep)
        d = item.delivery
        prev = item.t_sent
        if d is not None:
            for k, (a0, a1, lost) in enumerate(d.attempt_log):
                if a0 > prev:
                    add("radio_backoff", prev, a0, track=track,
                        cat="gateway", before_attempt=k + 1)
                add("radio_attempt", a0, a1, track=track, cat="gateway",
                    attempt=k + 1, lost=lost)
                prev = a1
            if d.delivered and item.t_arrive > prev:
                add("uplink", prev, item.t_arrive, track=track,
                    cat="gateway")
        if remote:
            prop = item.client.spec.channel.propagation_s
            if item.t_serve > item.t_arrive:
                add("queue_wait", item.t_arrive, item.t_serve, track=track,
                    cat="gateway")
            add("remote_batch", item.t_serve, t_done - prop, track=track,
                cat="gateway", slot=item.slot)
            add("response", t_done - prop, t_done, track=track,
                cat="gateway")
        m = tel.metrics
        m.counter("gateway.requests", status=status).inc()
        m.counter("gateway.radio_attempts").inc(item.attempts)
        m.histogram("gateway.e2e_ms", bounds=_MS_BOUNDS).observe(
            (t_done - item.t_born) * 1e3)

    # -------------------------------------------------------- event loop --
    def run(self, loop: "EventLoop | None" = None) -> GatewayReport:
        fleet, gw, faults = self.fleet, self.gw, self.faults
        t_wall = time.perf_counter()
        loop = loop if loop is not None else EventLoop()
        push = loop.push

        def born_at(client: int, j: int) -> float:
            """Request j's arrival instant, mapped through any scripted
            `ArrivalBurst` stampede (identity with no faults)."""
            t = float(fleet.clients[client].born[j])
            return faults.arrival_time(client, t) if faults is not None \
                else t

        next_req = [0] * len(fleet.clients)
        for c in fleet.clients:
            if c.spec.n_requests:
                push(born_at(c.index, 0), "dev", c.index)

        queue: list[_InFlight] = []
        busy = [False]
        epoch = [0]
        traces: list[RequestTrace] = []
        t_end = 0.0

        def resolve_local(item: _InFlight, t_done: float, status: str,
                          missed: bool) -> None:
            """Degradation floor: the device answers with its own
            Local-NN logits (bit-identical to the standalone local path —
            they were computed before the radio ever keyed up)."""
            nonlocal t_end
            p = item.payload
            row = item.client.row0 + p.req
            lrow = fleet.local_logits[row]
            e2e = t_done - item.t_born
            item.client.controller.observe(e2e)
            traces.append(RequestTrace(
                client=item.client.index, req=p.req,
                channel=item.client.spec.channel.name,
                bits=p.bits, keep=p.keep, payload_bytes=p.nbytes,
                attempts=item.attempts, t_born=item.t_born,
                t_sent=item.t_sent, t_arrive=item.t_arrive,
                t_serve=t_done, t_done=t_done, e2e_s=e2e,
                energy_j=item.energy_j, logits=lrow.copy(),
                pred=int(np.argmax(lrow)),
                label=int(fleet.labels[row]),
                status=status, deadline_missed=missed))
            t_end = max(t_end, t_done)
            self._note_request(item, t_done, status, remote=False)

        def start_batch(t0: float) -> None:
            epoch[0] += 1                    # pending window flushes lapse
            # shed-on-miss: a queued request whose deadline has lapsed by
            # launch time is pointless to serve — resolve it as a local
            # fallback (the device stopped waiting at its deadline)
            missed = [it for it in queue if it.deadline <= t0]
            if missed:
                queue[:] = [it for it in queue if it.deadline > t0]
                for it in missed:
                    resolve_local(it, it.deadline, "shed", True)
                if not queue:
                    return
            if any(it.deadline < math.inf for it in queue):
                queue.sort(key=lambda it: it.deadline)   # EDF; stable ->
            free = self._slots.free()                    # FIFO inside ties
            take, queue[:] = queue[:len(free)], queue[len(free):]
            for slot, item in zip(free, take):
                self._slots.acquire(slot, item)
                item.slot = slot             # slot id IS the batch row
            logits = self._batch_logits(take)
            for item in take:
                item.t_serve = t0
            service = self.server.server_time(
                len(take) * fleet.remote_macs)
            if faults is not None:           # stalled slot pool: the batch
                service += faults.server_stall_extra(t0)   # holds its slots
            if self.tel.enabled:
                self.tel.histogram(
                    "gateway.batch_size",
                    bounds=tuple(float(w) for w in
                                 range(1, gw.batch_width + 1))
                ).observe(len(take))
                self.tel.trace.add("remote_batch", t0, t0 + service,
                                   track="gateway", cat="gateway",
                                   batch=len(take))
            busy[0] = True
            push(t0 + service, "serve", (take, logits))

        while loop:
            t, kind, data = loop.pop()
            if kind == "dev":
                c = fleet.clients[data]
                j = next_req[data]
                born = born_at(data, j)
                payload = fleet.make_payload(c, j)   # profile at send time
                t_compute = fleet.compute_time(c)
                if faults is not None:
                    t_compute += faults.device_stall_extra(data, t)
                t_sent = t + t_compute
                deadline = (born + c.spec.deadline_ms * 1e-3
                            if c.spec.deadline_ms is not None else math.inf)
                d = c.channel.transmit(
                    payload.nbytes, t_sent,
                    deadline_s=None if deadline == math.inf else deadline,
                    link=faults.link(data) if faults is not None else None)
                energy = (c.device.p_cpu_w * t_compute
                          + c.device.p_tx_w * d.airtime_s)
                item = _InFlight(
                    payload=payload, client=c, t_born=born, t_start=t,
                    t_sent=t_sent, t_arrive=d.arrive_s,
                    attempts=d.attempts, energy_j=energy, deadline=deadline,
                    delivery=d)
                if faults is not None and d.delivered:
                    bad = faults.corrupt(data, t_sent, payload.codes)
                    if bad is not None:
                        item.payload = dataclasses.replace(payload,
                                                           codes=bad)
                if d.delivered:
                    push(d.arrive_s, "recv", item,
                         prio=deadline if deadline < math.inf else 0.0)
                else:
                    # radio gave up (dark link or deadline): Local-NN
                    # fallback at the moment it stopped retrying
                    resolve_local(item, d.device_free_s, "fallback",
                                  d.expired)
                next_req[data] = j + 1
                if j + 1 < c.spec.n_requests:
                    push(max(d.device_free_s, born_at(data, j + 1)),
                         "dev", data)
            elif kind == "recv":
                if data.deadline <= t:       # landed past its deadline:
                    resolve_local(data, data.deadline, "shed", True)
                    continue                 # the device already gave up
                if gw.max_queue is not None and len(queue) >= gw.max_queue:
                    # admission bound: overload is refused at the door —
                    # the device hears "rejected" now and serves its own
                    # Local-NN logits instead of parking in an unbounded
                    # backlog whose deadline it would miss anyway
                    resolve_local(data, t, "rejected", False)
                    continue
                queue.append(data)
                if self.tel.enabled:
                    self.tel.gauge("gateway.queue_depth").set(len(queue))
                if not busy[0]:
                    if len(queue) >= gw.batch_width:
                        start_batch(t)
                    else:
                        push(t + gw.batch_window_s, "flush", epoch[0])
            elif kind == "flush":
                if data == epoch[0] and not busy[0] and queue:
                    start_batch(t)
            elif kind == "serve":
                batch, logits = data
                busy[0] = False
                for item in batch:
                    self._slots.release(item.slot)
                    t_resp = t + item.client.spec.channel.propagation_s
                    push(t_resp, "resp", (item, logits[item.slot]))
                if queue:                    # backlog built up while busy
                    start_batch(t)
            elif kind == "resp":
                item, lrow = data
                e2e = t - item.t_born
                item.client.controller.observe(e2e)
                p = item.payload
                row = item.client.row0 + p.req
                traces.append(RequestTrace(
                    client=item.client.index, req=p.req,
                    channel=item.client.spec.channel.name,
                    bits=p.bits, keep=p.keep, payload_bytes=p.nbytes,
                    attempts=item.attempts, t_born=item.t_born,
                    t_sent=item.t_sent, t_arrive=item.t_arrive,
                    t_serve=item.t_serve, t_done=t, e2e_s=e2e,
                    energy_j=item.energy_j, logits=lrow.copy(),
                    pred=int(np.argmax(lrow)),
                    label=int(self.fleet.labels[row]),
                    status=item.status,
                    deadline_missed=t > item.deadline))
                t_end = max(t_end, t)
                self._note_request(item, t, item.status, remote=True)

        t_begin = min(born_at(c.index, 0) for c in fleet.clients
                      if c.spec.n_requests)
        return GatewayReport(traces=traces,
                             wall_s=time.perf_counter() - t_wall,
                             sim_s=float(t_end - t_begin),
                             n_clients=len(fleet.clients))
