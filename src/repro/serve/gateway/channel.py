"""Lossy rate-limited link between a weak device and the offload gateway.

Payload bytes translate into *time* instead of being free: every transmit
attempt pays the serialization delay (bytes * 8 / bandwidth) plus
propagation and uniform jitter; attempts are lost i.i.d. with
``drop_prob`` and retried after a retransmission timeout, so a degraded
channel stretches both the request's gateway-arrival time and the
radio-on seconds the device pays transmit energy for.  The final attempt
always delivers (the app layer keeps retrying; ``attempts`` records what
the retries cost), which keeps every simulated request accounted.

Presets mirror the paper's §7 links (ESP-WROOM WiFi at UDP 6 Mbps, a
270 kbps narrowband option) plus a lossy-WiFi variant for the rate
controller to push against.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    name: str = "wifi"
    bandwidth_bps: float = 6e6          # ESP-WROOM WiFi, UDP (paper §7)
    propagation_s: float = 2e-3
    jitter_s: float = 0.0               # uniform [0, jitter_s) per attempt
    drop_prob: float = 0.0              # i.i.d. per-attempt loss
    retransmit_timeout_s: float = 20e-3
    max_attempts: int = 8


WIFI_UDP = ChannelConfig()
NARROWBAND = ChannelConfig(name="narrowband", bandwidth_bps=270e3,
                           propagation_s=25e-3)
LOSSY_WIFI = ChannelConfig(name="lossy-wifi", drop_prob=0.15, jitter_s=3e-3)


@dataclasses.dataclass(frozen=True)
class Delivery:
    arrive_s: float          # payload reaches the gateway
    device_free_s: float     # radio released (device may start next request)
    airtime_s: float         # radio actively transmitting (tx energy)
    attempts: int


class Channel:
    """One device's link; owns a seeded RNG so fleet runs are
    deterministic and two same-seed channels replay identical loss/jitter
    sequences."""

    def __init__(self, cfg: ChannelConfig, seed: int = 0):
        self.cfg = cfg
        self._rng = np.random.RandomState(seed)

    def serialize_s(self, nbytes: int) -> float:
        return nbytes * 8.0 / self.cfg.bandwidth_bps

    def transmit(self, nbytes: int, t_send: float) -> Delivery:
        cfg = self.cfg
        ser = self.serialize_s(nbytes)
        t, attempts = t_send, 0
        while True:
            attempts += 1
            t += ser
            jitter = (float(self._rng.uniform(0.0, cfg.jitter_s))
                      if cfg.jitter_s > 0 else 0.0)
            if (attempts >= cfg.max_attempts
                    or float(self._rng.uniform()) >= cfg.drop_prob):
                break
            t += cfg.retransmit_timeout_s
        return Delivery(arrive_s=t + cfg.propagation_s + jitter,
                        device_free_s=t, airtime_s=attempts * ser,
                        attempts=attempts)
