"""Lossy rate-limited link between a weak device and the offload gateway.

Payload bytes translate into *time* instead of being free: every transmit
attempt pays the serialization delay (bytes * 8 / bandwidth) plus
propagation and uniform jitter; attempts are lost i.i.d. with
``drop_prob`` and retried after a retransmission timeout that backs off
exponentially (``backoff_mult``/``backoff_max_s``, optional jitter), so a
degraded channel stretches both the request's gateway-arrival time and
the radio-on seconds the device pays transmit energy for.

Delivery is *not* guaranteed.  Under the benign i.i.d. loss model the
final attempt still delivers (the app layer keeps retrying; ``attempts``
records what the retries cost), which keeps clean simulations fully
accounted.  But a fault-injected link (`repro.serve.faults`) can force
losses — a blackout or a Gilbert–Elliott bad state drops every attempt —
and a per-request ``deadline_s`` bounds how long the radio keeps trying;
when the retry budget or the deadline is exhausted `transmit` returns
``delivered=False`` and the caller degrades gracefully (Local-NN
fallback) instead of spinning.  ``max_attempts=0`` means "app retries
forever", but the channel still caps the loop (`RETRY_SAFETY_CAP`) so a
100%-loss link terminates the discrete-event loop as a failed delivery
rather than hanging it.

Presets mirror the paper's §7 links (ESP-WROOM WiFi at UDP 6 Mbps, a
270 kbps narrowband option) plus a lossy-WiFi variant for the rate
controller to push against.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

# attempts ceiling when max_attempts == 0 ("retry forever"): a blackout
# must end the transmit as a failed delivery, never hang the event loop
RETRY_SAFETY_CAP = 64


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    name: str = "wifi"
    bandwidth_bps: float = 6e6          # ESP-WROOM WiFi, UDP (paper §7)
    propagation_s: float = 2e-3
    jitter_s: float = 0.0               # uniform [0, jitter_s) per attempt
    drop_prob: float = 0.0              # i.i.d. per-attempt loss
    retransmit_timeout_s: float = 20e-3
    max_attempts: int = 8               # 0 = unbounded (RETRY_SAFETY_CAP
                                        # still bounds the transmit loop)
    backoff_mult: float = 1.0           # wait_i = timeout * mult**(i-1) ...
    backoff_max_s: float = math.inf     # ... capped here (1.0 = fixed wait)
    backoff_jitter: float = 0.0         # fraction of the wait drawn
                                        # uniformly on top (decorrelates
                                        # synchronized retries)

    def __post_init__(self):
        def bad(field, why):
            raise ValueError(f"ChannelConfig.{field} {why} "
                             f"(got {getattr(self, field)!r})")
        if not self.bandwidth_bps > 0:
            bad("bandwidth_bps", "must be > 0")
        if self.propagation_s < 0:
            bad("propagation_s", "must be >= 0")
        if self.jitter_s < 0:
            bad("jitter_s", "must be >= 0")
        if not 0.0 <= self.drop_prob <= 1.0:
            bad("drop_prob", "must be a probability in [0, 1]")
        if not self.retransmit_timeout_s > 0:
            bad("retransmit_timeout_s", "must be > 0")
        if self.max_attempts < 0:
            bad("max_attempts", "must be >= 0 (0 = retry forever)")
        if self.backoff_mult < 1.0:
            bad("backoff_mult", "must be >= 1.0")
        if not self.backoff_max_s > 0:
            bad("backoff_max_s", "must be > 0")
        if self.backoff_jitter < 0:
            bad("backoff_jitter", "must be >= 0")


WIFI_UDP = ChannelConfig()
NARROWBAND = ChannelConfig(name="narrowband", bandwidth_bps=270e3,
                           propagation_s=25e-3)
LOSSY_WIFI = ChannelConfig(name="lossy-wifi", drop_prob=0.15, jitter_s=3e-3)


@dataclasses.dataclass(frozen=True)
class Delivery:
    arrive_s: float          # payload reaches the gateway (gave up: = t_free)
    device_free_s: float     # radio released (device may start next request)
    airtime_s: float         # radio actively transmitting (tx energy)
    attempts: int
    delivered: bool = True   # False: retry budget / deadline exhausted
    expired: bool = False    # True: the per-request deadline stopped the
                             # retries (a deadline miss, not a dead link)
    attempt_log: tuple = ()  # per-attempt (t_start, t_end, lost) windows —
                             # the gaps between them are the backoff waits;
                             # telemetry turns each into a radio span


class Channel:
    """One device's link; owns a seeded RNG so fleet runs are
    deterministic and two same-seed channels replay identical loss/jitter
    sequences.  Fault randomness lives in the injector's per-client RNGs
    (`faults.LinkFaultView`), so attaching one never perturbs this
    channel's own draw sequence — a fault-free run is bit-identical with
    or without an (idle) injector."""

    def __init__(self, cfg: ChannelConfig, seed: int = 0):
        self.cfg = cfg
        self._rng = np.random.RandomState(seed)

    def serialize_s(self, nbytes: int) -> float:
        return nbytes * 8.0 / self.cfg.bandwidth_bps

    def _retry_wait(self, attempts: int) -> float:
        """Backoff before retry #attempts (the default mult=1.0 keeps the
        seed's fixed-timeout arithmetic bit-exact)."""
        cfg = self.cfg
        if cfg.backoff_mult == 1.0:
            wait = cfg.retransmit_timeout_s
        else:
            wait = min(cfg.retransmit_timeout_s
                       * cfg.backoff_mult ** (attempts - 1),
                       cfg.backoff_max_s)
        if cfg.backoff_jitter > 0:
            wait += float(self._rng.uniform(0.0, cfg.backoff_jitter * wait))
        return wait

    def transmit(self, nbytes: int, t_send: float, *,
                 deadline_s: "float | None" = None,
                 link=None) -> Delivery:
        """Push one payload; returns when it lands or the radio gives up.

        deadline_s: absolute simulated time after which no further retry
        is attempted (the in-flight attempt still completes).
        link: a `faults.LinkFaultView` forcing losses / scaling bandwidth.
        """
        cfg = self.cfg
        ser = self.serialize_s(nbytes)
        cap = cfg.max_attempts if cfg.max_attempts > 0 else RETRY_SAFETY_CAP
        t, attempts, airtime, scaled = t_send, 0, 0.0, False
        delivered, expired = True, False
        log: list = []
        while True:
            attempts += 1
            t_att = t                    # this attempt's on-air window start
            ser_i = ser
            if link is not None:
                scale = link.bandwidth_scale(t)
                if scale != 1.0:
                    ser_i, scaled = ser / scale, True
            t += ser_i
            airtime += ser_i
            jitter = (float(self._rng.uniform(0.0, cfg.jitter_s))
                      if cfg.jitter_s > 0 else 0.0)
            if link is not None and link.attempt_lost(t):
                # forced loss: no final-attempt rescue — a dark link
                # delivers nothing, however many times the app retries
                log.append((t_att, t, True))
                if attempts >= cap:
                    delivered = False
                    break
            elif (attempts >= cfg.max_attempts > 0
                    or float(self._rng.uniform()) >= cfg.drop_prob):
                log.append((t_att, t, False))
                break
            elif attempts >= cap:        # max_attempts == 0 under benign
                log.append((t_att, t, True))
                delivered = False        # 100% loss: the safety cap ends
                break                    # the loop as a failed delivery
            else:
                log.append((t_att, t, True))
            wait = self._retry_wait(attempts)
            if deadline_s is not None and t + wait >= deadline_s:
                delivered, expired = False, True   # no retry can land in time
                break
            t += wait
        if delivered and deadline_s is not None and t >= deadline_s:
            # the attempt itself overran the deadline (a slow link's
            # serialization alone can): a late arrival is a deadline miss,
            # not a delivery — report it like a stopped retry so the
            # caller degrades instead of pushing a stale payload upstream
            delivered, expired = False, True
        # fault-free fast path keeps the seed's closed-form airtime
        airtime = airtime if scaled else attempts * ser
        if not delivered:
            return Delivery(arrive_s=t, device_free_s=t, airtime_s=airtime,
                            attempts=attempts, delivered=False,
                            expired=expired, attempt_log=tuple(log))
        return Delivery(arrive_s=t + cfg.propagation_s + jitter,
                        device_free_s=t, airtime_s=airtime,
                        attempts=attempts, attempt_log=tuple(log))
