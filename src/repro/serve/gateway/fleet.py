"""A simulated fleet of weak devices running the AgileNN local path.

Each client owns a seeded Poisson arrival process, a link (`Channel`), a
rate controller and a slice of a fleet-wide synthetic request stream.
The device half of the pipeline (extractor -> fused top-k split/quantize
-> Local NN) runs *batched across the whole fleet* in one compiled call
(`core.agile.device_forward_fn`) when the fleet is built.  The host-side
radio framing is batched too: the first request sent under a rate
profile triggers one vectorized requantize + `pack_indices_batch` pass
and one LZW sweep over every fleet row at that framing, and all later
sends under the profile are cache hits — simulation time per request is
just the device/channel timing bookkeeping.  (The MCU's per-inference
codec cost is accounted in *simulated* time by the device model either
way; batching only removes redundant host work from the wall clock.)

Compute and transmit timestamps come from the `DeviceModel` cost model
(STM32F746-class MCU), with each client's link bandwidth taken from its
channel, so one fleet can mix WiFi and narrowband devices.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.compress.lzw import compress_payload, pack_indices_batch
from repro.compress.quantize import quantization_bits
from repro.configs.agilenn_cifar import AgileNNConfig
from repro.core.agile import device_forward_fn
from repro.data.synthetic import ImageDatasetSpec, SyntheticImages
from repro.serve.device_model import DeviceModel
from repro.serve.gateway.channel import (
    WIFI_UDP, NARROWBAND, LOSSY_WIFI, Channel, ChannelConfig,
)
from repro.serve.gateway.control import (
    RateController, default_ladder, keep_channels, requantize, subset_centers,
)
from repro.serve.offload import local_path_macs, remote_nn_macs


@dataclasses.dataclass(frozen=True)
class ClientSpec:
    channel: ChannelConfig = WIFI_UDP
    arrival_rate_hz: float = 25.0      # Poisson inference arrivals
    n_requests: int = 4
    slo_ms: "float | None" = None      # None => static configuration
    deadline_ms: "float | None" = None  # per-request deadline: the radio
                                        # stops retrying past it, the
                                        # gateway sheds on admission miss,
                                        # and the request resolves as a
                                        # Local-NN fallback

    def __post_init__(self):
        def bad(field, why):
            raise ValueError(f"ClientSpec.{field} {why} "
                             f"(got {getattr(self, field)!r})")
        if not isinstance(self.channel, ChannelConfig):
            bad("channel", "must be a ChannelConfig")
        if not self.arrival_rate_hz > 0:
            bad("arrival_rate_hz", "must be > 0")
        if self.n_requests < 0:
            bad("n_requests", "must be >= 0")
        if self.slo_ms is not None and not self.slo_ms > 0:
            bad("slo_ms", "must be > 0 (or None for the static profile)")
        if self.deadline_ms is not None and not self.deadline_ms > 0:
            bad("deadline_ms", "must be > 0 (or None for no deadline)")


def mixed_fleet(n_clients: int, *, n_requests: int = 4,
                arrival_rate_hz: float = 25.0,
                channels: tuple[ChannelConfig, ...] = (
                    WIFI_UDP, NARROWBAND, LOSSY_WIFI),
                slo_ms: "float | None" = None,
                deadline_ms: "float | None" = None) -> tuple[ClientSpec, ...]:
    """Round-robin mix of link types across the fleet."""
    return tuple(ClientSpec(channel=channels[i % len(channels)],
                            arrival_rate_hz=arrival_rate_hz,
                            n_requests=n_requests, slo_ms=slo_ms,
                            deadline_ms=deadline_ms)
                 for i in range(n_clients))


@dataclasses.dataclass
class Payload:
    """One radio frame: LZW-compressed bit-packed quantization indices.

    ``bits``/``keep``/``count`` describe the framing (in a real system a
    one-byte header; accounted as free here); ``codes`` is the LZW code
    stream actually on the air — the gateway's decode recovers the
    bit-packed indices from it."""
    client: int
    req: int
    bits: int
    keep: int            # transmitted remote channels (importance prefix)
    count: int           # packed index count = feat_hw^2 * keep
    nbytes: int          # radio bytes after LZW
    codes: list


class DeviceClient:
    """Host-side state of one simulated device."""

    def __init__(self, index: int, spec: ClientSpec, cfg: AgileNNConfig,
                 row0: int, ladder, seed: int):
        self.index = index
        self.spec = spec
        self.row0 = row0
        self.device = DeviceModel(cpu_hz=cfg.mcu_hz,
                                  link_bps=spec.channel.bandwidth_bps,
                                  macs_per_cycle=cfg.mcu_macs_per_cycle)
        self.channel = Channel(spec.channel, seed=seed + 1)
        slo_s = None if spec.slo_ms is None else spec.slo_ms * 1e-3
        self.controller = RateController(ladder, slo_s)
        rng = np.random.RandomState(seed)
        self.born = np.cumsum(rng.exponential(
            1.0 / spec.arrival_rate_hz, spec.n_requests))


class Fleet:
    """The device side of the gateway simulation, batched where the math
    is heavy and per-request where the radio framing is."""

    def __init__(self, cfg: AgileNNConfig, params,
                 specs: tuple[ClientSpec, ...], *, seed: int = 0):
        assert specs, "empty fleet"
        self.cfg = cfg
        self.params = params
        self.seed = seed
        feat_hw = cfg.image_size // (2 ** cfg.extractor_layers)
        self.feat_hw = feat_hw
        self.n_remote = cfg.extractor_channels - cfg.agile.k
        self.local_macs = local_path_macs(cfg, feat_hw)
        self.remote_macs = remote_nn_macs(cfg, feat_hw)

        centers = np.asarray(params["quant"]["centers"], np.float32)
        self.full_bits = quantization_bits(centers.shape[0])
        self._centers = {self.full_bits: centers}
        ladder = default_ladder(centers.shape[0])

        self.clients: list[DeviceClient] = []
        row0 = 0
        for i, spec in enumerate(specs):
            self.clients.append(DeviceClient(
                i, spec, cfg, row0, ladder, seed=seed + 101 * i))
            row0 += spec.n_requests
        self.n_requests = row0

        # fleet-wide request stream + one batched device pass (this is
        # the only compiled call the fleet makes; everything at
        # simulation time is numpy / pure python)
        data = SyntheticImages(ImageDatasetSpec(
            image_size=cfg.image_size, n_classes=cfg.n_classes, seed=seed))
        self.images, self.labels = data.batch(self.n_requests,
                                              seed=seed + 1)
        local_logits, f_remote, idx = device_forward_fn(cfg, params)(
            params, jnp.asarray(self.images))
        self.local_logits = np.asarray(local_logits)
        self.f_remote = np.asarray(f_remote, np.float32)
        self.idx = np.asarray(idx)
        # per-profile payload cache, filled fleet-wide on first use: one
        # vectorized requantize + pack_indices_batch pass and one LZW
        # sweep per (bits, keep) framing, so simulation-time make_payload
        # is a dict hit — the codec cost is paid once per profile inside
        # the measured pipeline, not once per request
        self._payloads: dict[tuple[int, int], list] = {}

    def centers_for(self, bits: int) -> np.ndarray:
        if bits not in self._centers:
            self._centers[bits] = subset_centers(
                self._centers[self.full_bits], bits)
        return self._centers[bits]

    def compute_time(self, client: DeviceClient) -> float:
        return client.device.compute_time(self.local_macs)

    def _encoded_rows(self, bits: int, keep: int) -> list:
        """(nbytes, codes) for every fleet row under one framing, batched:
        the static profile reuses the fused kernel's full-codebook
        indices (byte-identical to per-image `pack_indices`, so that
        path stays bit-identical to the single-image offload); reduced
        profiles requantize the whole fleet's features in one pass."""
        got = self._payloads.get((bits, keep))
        if got is None:
            if bits >= self.full_bits and keep >= self.n_remote:
                idx = self.idx
            else:
                idx = requantize(self.f_remote[..., :keep],
                                 self.centers_for(bits))
            packed = pack_indices_batch(idx, bits)
            got = [compress_payload(p) for p in packed]
            self._payloads[(bits, keep)] = got
        return got

    def make_payload(self, client: DeviceClient, req: int) -> Payload:
        """One request's radio frame under the client's *current* rate
        profile, served from the per-profile fleet-wide codec cache."""
        prof = client.controller.profile()
        row = client.row0 + req
        keep = keep_channels(prof, self.n_remote, self.full_bits)
        nbytes, codes = self._encoded_rows(prof.bits, keep)[row]
        return Payload(client=client.index, req=req, bits=prof.bits,
                       keep=keep, count=self.feat_hw * self.feat_hw * keep,
                       nbytes=nbytes, codes=codes)
