"""Multi-client offload gateway: a fleet of simulated weak devices
driving the batched Remote-NN serving path end-to-end over lossy links.

  fleet   = Fleet(cfg, params, mixed_fleet(32), seed=0)
  report  = OffloadGateway(cfg, params, fleet).run()
  print(report.summary())
"""
from repro.serve.gateway.channel import (
    LOSSY_WIFI, NARROWBAND, WIFI_UDP, Channel, ChannelConfig, Delivery,
)
from repro.serve.gateway.control import (
    ERASED, RateController, RateProfile, default_ladder, keep_channels,
    requantize, subset_centers,
)
from repro.serve.gateway.fleet import (
    ClientSpec, DeviceClient, Fleet, Payload, mixed_fleet,
)
from repro.serve.gateway.gateway import (
    GatewayConfig, GatewayReport, OffloadGateway, RequestTrace,
)

__all__ = [
    "Channel", "ChannelConfig", "Delivery",
    "WIFI_UDP", "NARROWBAND", "LOSSY_WIFI",
    "ERASED", "RateController", "RateProfile", "default_ladder",
    "keep_channels", "requantize", "subset_centers",
    "ClientSpec", "DeviceClient", "Fleet", "Payload", "mixed_fleet",
    "GatewayConfig", "GatewayReport", "OffloadGateway", "RequestTrace",
]
