"""Overload-robust async streaming frontend over the continuous scheduler.

The engine's `generate` is drain-style: callers hand over a closed batch
and block for every token.  Production traffic is an open stream, and an
open stream's failure mode is overload — `ContinuousScheduler.submit`
accepts unbounded work, so a client stampede means unbounded queue
growth and blown deadlines.  This module makes overload a first-class,
*bounded* state:

  * **Per-request streaming** — typed per-token events (`FirstToken`,
    `Delta`, `Finish`) published as each decode chunk lands, through the
    scheduler's own overlap loop (`ContinuousScheduler.stream_cb`:
    overlap rounds stream from the drained chunk's snapshot, serialized
    rounds from the pool).  Consumed synchronously via `step()`/`run()`
    or as async iterators via `stream()` + `serve_forever()`.
  * **Admission control** — a bounded admission queue with priority
    classes (``INTERACTIVE > BATCH > BEST_EFFORT``), per-class default
    deadlines, and earliest-deadline-first order within a class (FIFO on
    ties, like the gateway's event heap).  Admission beyond
    ``max_queue``, or past the estimated-queueing-delay SLO budget,
    raises a typed `Overloaded` carrying a retry-after hint — the
    *rejected* rung of the PR-6 degradation ladder, one step above
    *shed* (rejected work never cost a prefill; shed work at least
    arrived).
  * **Backpressure** — the frontend feeds the scheduler only as fast as
    the decode slot pool drains (`feed_depth` meters the scheduler's
    backlog), so saturation surfaces at admission instead of deep in
    the pool; ``stream(..., wait=True)`` turns the rejection into an
    awaited slow-down.  A circuit breaker opens at a high-water queue
    depth, sheds BEST_EFFORT traffic first, and recovers
    *hysteretically* — it only re-admits once depth falls below the
    low-water mark, so a saturated pool cannot flap between accept and
    reject.
  * **Priority preemption** — with ``SchedulerConfig.preempt``, an
    INTERACTIVE request that has waited past ``preempt_wait_ms`` with
    the pool full *suspends* the lowest-priority, latest-deadline
    pooled row mid-decode (`ContinuousScheduler.suspend`): the victim
    re-enters its class queue with its partial tokens preserved and
    resumes bit-identically when the pool drains — the suspended →
    resumed lifecycle, one rung gentler than *shed*.
  * **Request journal** — an attached `recovery.RequestJournal` records
    submit/admit/token-chunk/preempt/finish write-ahead on the shared
    clock timeline; after an `EngineCrash`, `recovery.recover` replays
    the journal into a fresh frontend (`restore`) and regenerates every
    in-flight request's tokens bit-identically, with exactly-once
    `Finish` delivery.
  * **One clock** — the frontend, the scheduler's deadline evictions and
    the simulated drivers all read the same injectable clock
    (`VirtualClock` / `repro.serve.event_loop.EventLoop.now`), the same
    discipline the offload gateway's discrete-event heap uses — so the
    overload benches are deterministic simulations, like the gateway's.

Bit-identity contract (tested): with overload features disabled — no
``max_queue``, no SLO, no class deadlines, one priority class — the
frontend is a pass-through: every request is fed to the scheduler in
submission order and greedy tokens are bit-identical to calling
`ContinuousScheduler.submit` + `run()` directly.  Attaching a stream
callback never changes tokens (it only reads), and a run with no
subscriber does no extra device->host copies.
"""
from __future__ import annotations

import asyncio
import dataclasses
import enum
import heapq
import itertools
import math
import time
from typing import Optional

import numpy as np

from repro.configs.base import ArchConfig
from repro.serve.scheduler import (
    ContinuousScheduler,
    SchedulerConfig,
    Suspended,
)

# the full degradation ladder, most to least service delivered; the
# frontend itself resolves requests as served / shed / rejected, the
# offload gateway adds degraded / fallback (repro.serve.gateway)
LADDER = ("served", "degraded", "shed", "rejected", "fallback")

DEFAULT_RETRY_S = 0.05      # retry-after hint before any throughput
                            # estimate exists (nothing has completed yet)


class Priority(enum.IntEnum):
    """Admission priority classes, most to least important.  Lower value
    admits first; the circuit breaker sheds from the bottom up."""
    INTERACTIVE = 0
    BATCH = 1
    BEST_EFFORT = 2

    @classmethod
    def parse(cls, name: str) -> "Priority":
        key = name.strip().upper().replace("-", "_")
        try:
            return cls[key]
        except KeyError:
            raise ValueError(
                f"unknown priority {name!r} (expected one of "
                f"{[p.name.lower().replace('_', '-') for p in cls]})")


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Admission-control knobs.  The defaults disable every overload
    feature (unbounded queue, no SLO, no class deadlines): the frontend
    is then a pure streaming pass-through over the scheduler."""
    max_queue: Optional[int] = None   # bound on admitted-but-unscheduled
                                      # requests (frontend + scheduler
                                      # backlog); None = unbounded
    slo_ms: Optional[float] = None    # queueing-delay budget: reject when
                                      # the estimated wait exceeds it
    class_deadline_ms: tuple = (None, None, None)
                                      # per-Priority default deadline
                                      # applied when a request carries
                                      # none (INTERACTIVE, BATCH,
                                      # BEST_EFFORT); None = no deadline
    breaker_high: float = 0.75        # breaker opens at this fraction of
                                      # max_queue ...
    breaker_low: float = 0.25         # ... and only closes again below
                                      # this one (hysteresis)
    feed_depth: Optional[int] = None  # scheduler backlog the feeder
                                      # maintains; None = max_slots +
                                      # prefill_group (keep the pool fed,
                                      # keep ordering at the frontend)
    ewma: float = 0.3                 # service-rate estimator smoothing
    preempt_wait_ms: float = 0.0      # INTERACTIVE queue-wait budget:
                                      # once an interactive waiter has
                                      # aged past it with the pool full,
                                      # the lowest-priority latest-
                                      # deadline pooled row is suspended
                                      # to make room (needs
                                      # SchedulerConfig.preempt; 0 =
                                      # preempt as soon as one waits)

    def __post_init__(self):
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(f"FrontendConfig.max_queue must be >= 1 or "
                             f"None (got {self.max_queue!r})")
        if self.slo_ms is not None and not self.slo_ms > 0:
            raise ValueError(f"FrontendConfig.slo_ms must be > 0 or None "
                             f"(got {self.slo_ms!r})")
        if not 0.0 <= self.breaker_low < self.breaker_high <= 1.0:
            raise ValueError(
                f"FrontendConfig breaker watermarks need "
                f"0 <= low < high <= 1, got low={self.breaker_low} "
                f"high={self.breaker_high}")
        if len(self.class_deadline_ms) != len(Priority):
            raise ValueError("FrontendConfig.class_deadline_ms needs one "
                             f"entry per priority class "
                             f"(got {self.class_deadline_ms!r})")
        if not 0.0 < self.ewma <= 1.0:
            raise ValueError(f"FrontendConfig.ewma must be in (0, 1], "
                             f"got {self.ewma!r}")
        if self.feed_depth is not None and self.feed_depth < 1:
            raise ValueError(f"FrontendConfig.feed_depth must be >= 1 or "
                             f"None (got {self.feed_depth!r})")
        if not self.preempt_wait_ms >= 0:
            raise ValueError(f"FrontendConfig.preempt_wait_ms must be "
                             f">= 0, got {self.preempt_wait_ms!r}")


# ------------------------------------------------------- typed events --


@dataclasses.dataclass(frozen=True)
class FirstToken:
    """The request's first generated token — TTFT is ``t`` minus the
    submission instant."""
    rid: int
    token: int
    t: float


@dataclasses.dataclass(frozen=True)
class Delta:
    """One subsequent token, published as its decode chunk lands."""
    rid: int
    token: int
    t: float


@dataclasses.dataclass(frozen=True)
class Finish:
    """Terminal event: ``status`` is a `LADDER` rung ("served" or
    "shed" from the frontend) and ``tokens`` the full output (partial
    when deadline-shed mid-decode)."""
    rid: int
    status: str
    tokens: np.ndarray
    t: float


class Overloaded(RuntimeError):
    """Typed admission rejection — the *rejected* ladder rung.

    ``retry_after_s`` is the frontend's estimate of when the queue will
    have drained below its high-water mark; a well-behaved client backs
    off at least that long.  ``queue_depth`` is the depth that triggered
    the refusal, ``reason`` one of "queue full" / "slo" / "breaker".
    """

    def __init__(self, reason: str, retry_after_s: float, queue_depth: int):
        self.reason = reason
        self.retry_after_s = float(retry_after_s)
        self.queue_depth = int(queue_depth)
        super().__init__(
            f"admission rejected ({reason}): queue depth {queue_depth}, "
            f"retry after {self.retry_after_s:.3f}s")


class VirtualClock:
    """Injectable simulated clock: reads return ``now``; a driver
    advances it.  Shared between the frontend and its scheduler, so
    deadlines, stream timestamps and admission estimates live on one
    deterministic timeline (the same posture as the gateway's
    `EventLoop.now`)."""

    def __init__(self, t0: float = 0.0):
        self.now = float(t0)

    def __call__(self) -> float:
        return self.now


# ---------------------------------------------------------- frontend --


class StreamingFrontend:
    """Admission-controlled streaming interface to one decode pool.

    `submit()` admits (or rejects, typed) a request into per-class EDF
    queues; `step()` runs one scheduler round, feeding admitted
    requests into the pool as it drains, and returns the round's stream
    events; `run()` drains everything (batch callers); `stream()` is
    the asyncio per-request iterator, driven by `serve_forever()`.
    """

    def __init__(self, cfg: ArchConfig, params, *,
                 frontend: Optional[FrontendConfig] = None,
                 sched: Optional[SchedulerConfig] = None,
                 max_len: int = 256, seed: int = 0, mesh=None,
                 clock=None, faults=None, telemetry=None, journal=None):
        """journal: a `repro.serve.recovery.RequestJournal` recording
        submit/admit/token-chunk/preempt/finish events on this
        frontend's clock timeline (write-ahead: every record lands
        before its effect is observable).  All journal writes reuse
        clock reads the frontend already makes, so an attached journal
        is a bit-identical pass-through for tokens and event
        timestamps; None (the default) skips the writes entirely."""
        from repro.serve import telemetry as _telemetry
        self.fcfg = frontend or FrontendConfig()
        self.journal = journal
        self.tel = telemetry if telemetry is not None else _telemetry.default()
        self._clock = clock if clock is not None else time.monotonic
        self.sched = ContinuousScheduler(
            cfg, params, sched=sched, max_len=max_len, seed=seed,
            mesh=mesh, clock=self._clock, faults=faults,
            telemetry=self.tel)
        self.sched.stream_cb = self._on_stream
        sc = self.sched.sched
        self._feed_cap = (self.fcfg.feed_depth if self.fcfg.feed_depth
                          is not None else sc.max_slots + sc.prefill_group)
        self._classes: list[list] = [[] for _ in Priority]  # EDF heaps of
        self._seq = itertools.count()            # (deadline, seq, rid)
        self._reqs: dict[int, object] = {}       # waiting rid -> Request
                                                 # (or Suspended: preempted,
                                                 # awaiting resume)
        self._deadline: dict[int, float] = {}    # rid -> absolute deadline
        self._prio: dict[int, Priority] = {}     # rid -> admission class
        self._t_submit: dict[int, float] = {}    # rid -> admission instant
                                                 # (reuses the submit clock
                                                 # read; preemption budgets
                                                 # age against it)
        self._next_rid = 0
        self._to_sched: dict[int, int] = {}
        self._from_sched: dict[int, int] = {}
        self._published: dict[int, int] = {}     # rid -> tokens emitted
        self._subs: dict[int, object] = {}       # rid -> event callback
        self._results: dict[int, tuple] = {}     # rid -> (status, tokens)
        self.events: list = []                   # every event, in order
        self.rejections: list = []               # (t, Priority, Overloaded)
        self.breaker_open = False
        self._rate: Optional[float] = None       # served requests / s
        self._t_admit: dict[int, float] = {}     # rid -> submit instant
                                                 # (telemetry-enabled only:
                                                 # queue_wait span starts)
        self._t_last = self._clock()
        self._step_events: list = []
        self._closed = False

    # ------------------------------------------------------ admission --

    def _n_waiting(self) -> int:
        return len(self._reqs)

    def queue_depth(self) -> int:
        """Admitted-but-unscheduled work: the frontend's EDF queues plus
        the scheduler backlog the feeder has already released.  This is
        the quantity `max_queue` bounds and the breaker watches."""
        return self._n_waiting() + self.sched.backlog()

    def _n_ahead(self, priority: Priority) -> int:
        """Work that must clear the pool before a new request of this
        class can start: everything waiting at its class or better, the
        scheduler backlog, and the requests already holding slots."""
        waiting = sum(len(self._classes[p]) for p in Priority
                      if p <= priority)
        pooled = sum(r is not None for r in self.sched._slot_rid)
        return waiting + self.sched.backlog() + pooled

    def est_delay_s(self, priority: Priority) -> float:
        """Estimated queueing delay for a new request of this class,
        from the EWMA of observed service rate.  Zero until the first
        completion lands (nothing to extrapolate from — admit)."""
        if not self._rate:
            return 0.0
        return self._n_ahead(priority) / self._rate

    def _retry_after(self, depth: int) -> float:
        """Hint: time for the queue to drain below the low-water mark at
        the observed service rate (the point the breaker would close)."""
        if self.fcfg.max_queue is not None:
            excess = depth - self.fcfg.breaker_low * self.fcfg.max_queue
        else:
            excess = depth
        excess = max(excess, 1.0)
        if self._rate:
            return excess / self._rate
        if self.fcfg.slo_ms is not None:
            return self.fcfg.slo_ms * 1e-3
        return DEFAULT_RETRY_S

    def _update_breaker(self) -> None:
        if self.fcfg.max_queue is None:
            return
        was = self.breaker_open
        depth = self.queue_depth()
        if depth >= self.fcfg.breaker_high * self.fcfg.max_queue:
            self.breaker_open = True
        elif depth <= self.fcfg.breaker_low * self.fcfg.max_queue:
            self.breaker_open = False
        if self.tel.enabled and was != self.breaker_open:
            self.tel.counter(
                "frontend.breaker_transitions",
                to="open" if self.breaker_open else "closed").inc()

    def _reject(self, reason: str, priority: Priority, depth: int):
        err = Overloaded(reason, self._retry_after(depth), depth)
        self.rejections.append((self._clock(), priority, err))
        if self.tel.enabled:
            self.tel.counter("frontend.admission", verdict="rejected",
                             reason=reason.replace(" ", "_"),
                             priority=priority.name).inc()
        raise err

    def submit(self, request, priority: Priority = Priority.INTERACTIVE,
               ) -> int:
        """Admit one request; returns its stream id.  Raises `Overloaded`
        (typed, with a retry-after hint) when the queue is at its bound,
        the estimated queueing delay exceeds the SLO budget, or the
        circuit breaker is open and the request is BEST_EFFORT."""
        priority = Priority(priority)
        self._update_breaker()
        depth = self.queue_depth()
        if self.breaker_open and priority == Priority.BEST_EFFORT:
            self._reject("breaker", priority, depth)
        if self.fcfg.max_queue is not None and depth >= self.fcfg.max_queue:
            self._reject("queue full", priority, depth)
        if self.fcfg.slo_ms is not None:
            est = self.est_delay_s(priority)
            if est > self.fcfg.slo_ms * 1e-3:
                self._reject("slo", priority, depth)
        now = self._clock()
        rid = self._next_rid
        self._next_rid += 1
        dl_s = request.deadline_s
        if dl_s is None:
            dl_ms = self.fcfg.class_deadline_ms[priority]
            dl_s = None if dl_ms is None else dl_ms * 1e-3
        deadline = math.inf if dl_s is None else now + dl_s
        self._reqs[rid] = request
        self._deadline[rid] = deadline
        self._prio[rid] = priority
        self._t_submit[rid] = now
        if self.journal is not None:
            self._journal_submit(rid, request, priority, deadline, now)
        if self.tel.enabled:
            self.tel.counter("frontend.admission", verdict="admitted",
                             priority=priority.name).inc()
            self._t_admit[rid] = now     # queue_wait span start (reuses
                                         # the admission clock read)
        heapq.heappush(self._classes[priority],
                       (deadline, next(self._seq), rid))
        if self.fcfg.max_queue is None:
            self._feed()          # pass-through: the scheduler sees the
        return rid                # exact submission order, unmetered

    # -------------------------------------------------------- feeding --

    def _journal_submit(self, rid: int, request, priority: Priority,
                        deadline: float, now: float) -> None:
        """Write-ahead record of everything recovery needs to re-create
        this admission: the prompt, budget, sampling knobs, class, and
        absolute deadline (on the shared clock timeline)."""
        self.journal.append(
            "submit", rid, now,
            prompt=np.asarray(request.tokens, np.int64).tolist(),
            max_new=int(request.max_new_tokens),
            eos=int(request.eos_id), temp=float(request.temperature),
            prio=priority.name,
            deadline=None if deadline == math.inf else float(deadline))

    def _feed(self) -> None:
        """Release admitted requests into the scheduler, best class
        first and EDF within it, while the scheduler backlog is below
        the feed depth (unmetered when no queue bound is set).  Requests
        whose deadline already lapsed while waiting resolve as *shed*
        without ever costing a prefill — a suspended one resolves with
        the tokens it generated before preemption."""
        while True:
            if (self.fcfg.max_queue is not None
                    and self.sched.backlog() >= self._feed_cap):
                return
            item = None
            for p in Priority:
                if self._classes[p]:
                    item = heapq.heappop(self._classes[p])
                    break
            if item is None:
                return
            deadline, _, rid = item
            req = self._reqs.pop(rid)
            now = self._clock()          # one read per item, as before
            if deadline <= now:
                self._shed_waiting(rid, req)
                continue
            if self.tel.enabled and rid in self._t_admit:
                self.tel.trace.add("queue_wait", self._t_admit.pop(rid),
                                   now, track=f"req {rid}", cat="frontend")
            deadline_at = None if deadline == math.inf else deadline
            if isinstance(req, Suspended):
                srid = self.sched.submit_suspended(req,
                                                   deadline_at=deadline_at)
            else:
                srid = self.sched.submit(req, deadline_at=deadline_at)
            self._to_sched[rid] = srid
            self._from_sched[srid] = rid
            if self.journal is not None:
                self.journal.append("admit", rid, now)

    def _shed_waiting(self, rid: int, req) -> None:
        """Resolve a waiting request as shed; a suspended one keeps its
        pre-preemption tokens (preemption never silently drops work) and
        releases its parked prefix pins."""
        if isinstance(req, Suspended):
            self.sched.discard_suspended(req)
            self._finish_local(rid, "shed",
                               toks=np.asarray(req.generated, np.int32))
        else:
            self._finish_local(rid, "shed")

    def _expire_waiting(self) -> None:
        """Shed waiting requests whose deadline lapsed in the queue (the
        EDF heap keeps them at the front of their class)."""
        now = self._clock()
        for p in Priority:
            h = self._classes[p]
            while h and h[0][0] <= now:
                _, _, rid = heapq.heappop(h)
                self._shed_waiting(rid, self._reqs.pop(rid))

    # ----------------------------------------------------- preemption --

    def _maybe_preempt(self) -> None:
        """Make room for aged INTERACTIVE waiters by suspending pooled
        lower-class rows (`SchedulerConfig.preempt` gates this; off by
        default, so the pass-through contract is untouched).  The victim
        is the lowest-priority, latest-deadline pooled row; it re-enters
        its own class queue as a `Suspended` — bypassing admission
        control, so a preempted request can never be rejected or
        silently dropped — and resumes bit-identically when the pool
        drains.  Waiters only become visible here while they sit in the
        frontend's class queues, i.e. under a bounded `max_queue` with a
        feeder metering the scheduler backlog."""
        if not self.sched.sched.preempt:
            return
        h = self._classes[Priority.INTERACTIVE]
        if not h or self.sched._free_slots():
            return
        now = self._clock()
        budget = self.fcfg.preempt_wait_ms * 1e-3
        waiters = sum(1 for _, _, rid in h
                      if now - self._t_submit.get(rid, now) >= budget)
        if not waiters:
            return
        stag = self.sched._staging_slots()
        cands = []
        for slot, srid in enumerate(self.sched._slot_rid):
            if srid is None or slot in stag:
                continue
            rid = self._from_sched.get(srid)
            if rid is None:
                continue
            prio = self._prio.get(rid, Priority.INTERACTIVE)
            if prio > Priority.INTERACTIVE:
                cands.append((int(prio),
                              self._deadline.get(rid, math.inf), rid, srid))
        cands.sort(reverse=True)         # worst class, latest deadline
        for _, _, rid, srid in cands[:waiters]:
            sus = self.sched.suspend(srid)
            if sus is None:
                continue                 # already finished: drains normally
            del self._from_sched[srid]
            del self._to_sched[rid]
            prio = self._prio[rid]
            self._reqs[rid] = sus
            heapq.heappush(self._classes[prio],
                           (self._deadline.get(rid, math.inf),
                            next(self._seq), rid))
            if self.journal is not None:
                self.journal.append("preempt", rid, now,
                                    n=int(len(sus.generated)))
            if self.tel.enabled:
                self.tel.counter("frontend.preempted",
                                 victim=prio.name).inc()

    # --------------------------------------------------------- events --

    def _emit(self, ev) -> None:
        self.events.append(ev)
        self._step_events.append(ev)
        sub = self._subs.get(ev.rid)
        if sub is not None:
            sub(ev)

    def _emit_tokens(self, rid: int, toks: np.ndarray) -> None:
        """Publish any not-yet-seen prefix tokens as FirstToken/Delta."""
        n = self._published.get(rid, 0)
        if len(toks) <= n:
            return
        t = self._clock()
        if self.journal is not None:     # write-ahead: the chunk is
            self.journal.append(         # durable before it is emitted
                "chunk", rid, t, toks=[int(x) for x in toks[n:]])
        for k in range(n, len(toks)):
            cls = FirstToken if k == 0 else Delta
            self._emit(cls(rid, int(toks[k]), t))
        self._published[rid] = len(toks)

    def _on_stream(self, srid: int, toks: np.ndarray) -> None:
        """`ContinuousScheduler.stream_cb`: tokens-so-far for a live
        pooled request, once per scheduling round."""
        rid = self._from_sched.get(srid)
        if rid is not None:
            self._emit_tokens(rid, toks)

    def _finish_local(self, rid: int, status: str, *,
                      toks: Optional[np.ndarray] = None) -> None:
        """Resolve a request without a scheduler completion: a queue
        shed (no tokens) or a preempted-then-shed suspension (``toks``
        carries its pre-preemption output, tail-published first so the
        stream and the journal both see every token)."""
        self._deadline.pop(rid, None)
        self._prio.pop(rid, None)
        self._t_submit.pop(rid, None)
        if toks is None:
            toks = np.zeros((0,), np.int32)
        if len(toks):
            self._emit_tokens(rid, toks)     # tail the stream never saw
        self._published.pop(rid, None)
        self._results[rid] = (status, toks)
        t = self._clock()
        if self.tel.enabled:
            self.tel.counter("frontend.finish", status=status).inc()
            t0 = self._t_admit.pop(rid, None)
            if t0 is not None:
                self.tel.trace.add("queue_wait", t0, t,
                                   track=f"req {rid}", cat="frontend",
                                   status=status)
        if self.journal is not None:
            self.journal.append("finish", rid, t, status=status,
                                n=int(len(toks)))
        self._emit(Finish(rid, status, toks, t))

    def _finish_sched(self, srid: int) -> str:
        rid = self._from_sched.pop(srid)
        self._to_sched.pop(rid)
        self._deadline.pop(rid, None)
        self._prio.pop(rid, None)
        self._t_submit.pop(rid, None)
        comp = self.sched.pop_completion(srid)
        toks = np.asarray(comp.tokens)
        self._emit_tokens(rid, toks)     # tail the stream never saw
        self._published.pop(rid, None)
        status = "shed" if comp.timed_out else "served"
        self._results[rid] = (status, toks)
        if self.tel.enabled:
            self.tel.counter("frontend.finish", status=status).inc()
        t = self._clock()
        if self.journal is not None:
            self.journal.append("finish", rid, t, status=status,
                                n=int(len(toks)))
        self._emit(Finish(rid, status, toks, t))
        return status

    # ----------------------------------------------------------- loop --

    def has_work(self) -> bool:
        return bool(self._n_waiting() or self.sched.has_work())

    def step(self) -> list:
        """One frontend round: shed expired waiters, feed the scheduler
        up to the backpressure depth, run one scheduler round, resolve
        its completions, update the service-rate estimate and the
        breaker.  Returns this round's events, in emission order."""
        self._step_events = []
        self._expire_waiting()
        self._maybe_preempt()
        self._feed()
        done = self.sched.step()
        n_served = sum(self._finish_sched(srid) == "served"
                       for srid in done)
        now = self._clock()
        dt = now - self._t_last
        self._t_last = now
        if n_served and dt > 0:
            inst = n_served / dt
            a = self.fcfg.ewma
            self._rate = (inst if self._rate is None
                          else (1 - a) * self._rate + a * inst)
        self._update_breaker()
        if self.tel.enabled:
            m = self.tel.metrics
            for p in Priority:
                m.gauge("frontend.queue_depth",
                        priority=p.name).set(len(self._classes[p]))
            m.gauge("frontend.queue_depth_total").set(self.queue_depth())
            m.gauge("frontend.service_rate_rps").set(self._rate or 0.0)
            m.gauge("frontend.breaker_open").set(int(self.breaker_open))
        return self._step_events

    def run(self) -> dict:
        """Drain every admitted request; returns (and forgets)
        {rid: (status, tokens)} — statuses are LADDER rungs ("served" /
        "shed"; rejected submissions raised `Overloaded` instead and
        appear in `self.rejections`)."""
        while self.has_work():
            self.step()
        out, self._results = self._results, {}
        return out

    # ------------------------------------------------------- recovery --

    def restore(self, rid: int, request,
                priority: Priority = Priority.INTERACTIVE, *,
                deadline_at: Optional[float] = None,
                generated=None) -> int:
        """Re-install a journaled request under its *original* rid after
        a crash (`serve.recovery.recover` drives this).  Admission
        control is bypassed — the request was already admitted before
        the crash, so re-rejecting it would lose accepted work.  With
        ``generated`` (the journaled token chunks) it re-enters as a
        `Suspended` and resumes through the ordinary prefill path;
        `_published` starts past those tokens, so the pre-crash stream
        is never re-emitted and exactly one `Finish` is ever published
        per rid across the crashed and recovered frontends.  The
        restoration is re-journaled (submit + chunk), so the recovered
        frontend's own journal is self-contained against a second
        crash."""
        priority = Priority(priority)
        assert rid not in self._reqs and rid not in self._to_sched \
            and rid not in self._results, f"rid {rid} already live here"
        self._next_rid = max(self._next_rid, rid + 1)
        now = self._clock()
        deadline = math.inf if deadline_at is None else float(deadline_at)
        gen = np.asarray([] if generated is None else generated, np.int32)
        item = request
        if len(gen):
            item = Suspended(request, gen,
                             None if deadline == math.inf else deadline,
                             None)
        self._reqs[rid] = item
        self._deadline[rid] = deadline
        self._prio[rid] = priority
        self._t_submit[rid] = now
        self._published[rid] = len(gen)  # pre-crash tokens were streamed
        if self.journal is not None:
            self._journal_submit(rid, request, priority, deadline, now)
            if len(gen):
                self.journal.append("chunk", rid, now,
                                    toks=[int(x) for x in gen])
        if self.tel.enabled:
            self.tel.counter("frontend.admission", verdict="restored",
                             priority=priority.name).inc()
            self._t_admit[rid] = now
        heapq.heappush(self._classes[priority],
                       (deadline, next(self._seq), rid))
        if self.fcfg.max_queue is None:
            self._feed()
        return rid

    # ---------------------------------------------------------- async --

    async def stream(self, request,
                     priority: Priority = Priority.INTERACTIVE, *,
                     wait: bool = False, poll_s: float = 0.0):
        """Async iterator of this request's typed events, ending with
        `Finish`.  With ``wait=True`` an `Overloaded` rejection of an
        INTERACTIVE/BATCH request becomes backpressure: the caller
        sleeps the retry-after hint and retries instead of failing
        (BEST_EFFORT always fails fast — it is what the breaker sheds).
        Run `serve_forever()` on the same loop to drive the rounds."""
        while True:
            try:
                rid = self.submit(request, priority)
                break
            except Overloaded as e:
                if not wait or priority == Priority.BEST_EFFORT:
                    raise
                await asyncio.sleep(max(e.retry_after_s, poll_s))
        q: asyncio.Queue = asyncio.Queue()
        self._subs[rid] = q.put_nowait
        try:
            while True:
                ev = await q.get()
                yield ev
                if isinstance(ev, Finish):
                    return
        finally:
            self._subs.pop(rid, None)

    async def serve_forever(self, *, idle_s: float = 1e-3) -> None:
        """Round driver for the async API: runs `step()` whenever work
        exists, yields to submitters between rounds, idles otherwise.
        `close()` stops it after the current round."""
        self._closed = False
        while not self._closed:
            if self.has_work():
                self.step()
                await asyncio.sleep(0)       # let submitters interleave
            else:
                await asyncio.sleep(idle_s)

    def close(self) -> None:
        self._closed = True


# ------------------------------------------------- simulated workload --


@dataclasses.dataclass(frozen=True)
class SimClient:
    """One closed-loop client: issues ``requests`` in order, the next
    ``think_s`` after the previous resolves (served, shed or rejected).
    ``start_s`` is the nominal first-arrival instant — mapped through
    any scripted `ArrivalBurst` by the driver, so a stampede compresses
    the fleet's session starts exactly like the gateway's arrivals."""
    requests: tuple
    priority: Priority = Priority.INTERACTIVE
    start_s: float = 0.0
    think_s: float = 0.0


@dataclasses.dataclass
class SimRecord:
    client: int
    priority: Priority
    t_submit: float
    status: str = ""                  # served | shed | rejected
    t_first: float = math.nan
    t_done: float = math.nan
    n_tokens: int = 0
    retry_after_s: float = 0.0
    token_ts: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class SimReport:
    """Per-request outcomes of one simulated closed-loop run, plus the
    metric views the SLO bench and the overload tests share."""
    records: list
    sim_s: float

    def of(self, *prios: Priority) -> list:
        return [r for r in self.records if r.priority in prios]

    def status_rate(self, *statuses: str) -> float:
        return float(np.mean([r.status in statuses for r in self.records]))

    @property
    def reject_rate(self) -> float:
        return self.status_rate("rejected")

    @property
    def goodput_rps(self) -> float:
        """Served (in-deadline, token-bearing) requests per simulated
        second — the half of the offered load that became useful work."""
        n = sum(r.status == "served" for r in self.records)
        return n / self.sim_s if self.sim_s > 0 else 0.0

    def ttft_ms(self, *prios: Priority) -> np.ndarray:
        recs = self.of(*prios) if prios else self.records
        return np.asarray([(r.t_first - r.t_submit) * 1e3 for r in recs
                           if r.status == "served"])

    def itl_ms(self) -> np.ndarray:
        """Inter-token gaps across every served multi-token request."""
        gaps: list[float] = []
        for r in self.records:
            if r.status == "served" and len(r.token_ts) > 1:
                gaps.extend(np.diff(np.asarray(r.token_ts)) * 1e3)
        return np.asarray(gaps)


def drive_closed_loop(fe: StreamingFrontend, clients: list[SimClient], *,
                      clock: VirtualClock, round_s: float,
                      faults=None) -> SimReport:
    """Run a closed-loop fleet against a frontend on a virtual clock.

    Each scheduler round costs ``round_s`` of simulated time (the
    discrete-event stand-in for the decode chunk's service time — the
    same modeling move the gateway makes with `DeviceModel`); arrivals
    due at or before the current instant submit between rounds, and a
    client whose request resolves — or is rejected — schedules its next
    one ``think_s`` later.  Deterministic end to end: tokens are greedy
    and seeded, the clock only moves by round arithmetic, and rejection
    decisions depend on nothing but queue state and the clock — so the
    SLO bench pins its TTFT/ITL/reject-rate rows as exact values, the
    way every gateway row is pinned.
    """
    assert clock() == clock.now, "frontend and driver must share the clock"
    n_next = [0] * len(clients)      # next request index per client
    due = []                         # (t, client) heap of pending submits
    for c, cl in enumerate(clients):
        if cl.requests:
            t0 = cl.start_s
            if faults is not None:
                t0 = faults.arrival_time(c, t0)
            heapq.heappush(due, (t0, c))
    records: list[SimRecord] = []
    live: dict[int, SimRecord] = {}  # frontend rid -> record
    t0 = min(t for t, _ in due) if due else 0.0
    t_end = t0

    def submit_due() -> None:
        nonlocal t_end
        while due and due[0][0] <= clock.now:
            _, c = heapq.heappop(due)
            cl = clients[c]
            j = n_next[c]
            n_next[c] = j + 1
            rec = SimRecord(client=c, priority=cl.priority,
                            t_submit=clock.now)
            records.append(rec)
            try:
                rid = fe.submit(cl.requests[j], cl.priority)
                live[rid] = rec
            except Overloaded as e:
                rec.status = "rejected"
                rec.t_done = clock.now
                rec.retry_after_s = e.retry_after_s
                t_end = max(t_end, clock.now)
                if j + 1 < len(cl.requests):
                    heapq.heappush(due, (clock.now + cl.think_s, c))

    while due or fe.has_work():
        submit_due()
        if not fe.has_work():
            # idle frontend: jump the clock to the next arrival
            clock.now = max(clock.now, due[0][0])
            continue
        clock.now += round_s         # this round's service time elapses
        for ev in fe.step():
            rec = live.get(ev.rid)
            if rec is None:
                continue
            if isinstance(ev, (FirstToken, Delta)):
                if isinstance(ev, FirstToken):
                    rec.t_first = ev.t
                rec.token_ts.append(ev.t)
            elif isinstance(ev, Finish):
                live.pop(ev.rid)
                rec.status = ev.status
                rec.t_done = ev.t
                rec.n_tokens = len(ev.tokens)
                t_end = max(t_end, ev.t)
                c = rec.client
                if n_next[c] < len(clients[c].requests):
                    heapq.heappush(due, (ev.t + clients[c].think_s, c))
    return SimReport(records=records, sim_s=t_end - t0)
