"""Seeded, scriptable fault injection for the offload serving stack.

The gateway simulation's benign failure model (i.i.d. drops with a fixed
retransmit timeout) never exercises the failure modes a weak-device
deployment actually sees: burst loss on a fading channel, a link that
goes dark for hundreds of milliseconds, a device stalled by an interrupt
storm, a gateway whose slot pool stops draining.  This module models
those as *deterministic, seeded schedules* so chaos runs replay exactly:

  * ``Blackout``        — a window during which every transmit attempt on
    the affected links is lost (forced drops, no final-attempt rescue).
  * ``BurstLoss``       — a Gilbert–Elliott two-state channel: a per-link
    Markov chain alternates between a good state (low loss) and a bad
    state (near-total loss), advanced one step per transmit attempt.
  * ``LinkDegrade``     — a window of reduced bandwidth and/or extra
    i.i.d. loss on the affected links.
  * ``DeviceStall``     — extra on-device compute latency in a window
    (GC pause / interrupt storm on the MCU).
  * ``GatewayStall``    — extra Remote-NN service latency for batches
    launched in a window (the slot pool holds its slots longer).
  * ``PayloadCorruption`` — delivered payloads have their LZW code
    stream flipped or truncated; the gateway's hardened decode turns
    this into a typed erasure instead of a crash.
  * ``ArrivalBurst``      — a client stampede: arrivals nominally spread
    over a window land compressed toward its start, multiplying offered
    load by ``factor`` without changing total demand.  Consumed by the
    gateway's arrival events and by the streaming frontend's simulated
    driver, so overload is scriptable and replayable like every other
    fault.
  * ``EngineCrash``       — the decode scheduler dies at the start of a
    given round (`EngineCrashError`), losing the pool and every
    in-flight request; `serve.recovery` replays them from the request
    journal.

`FaultInjector` owns all fault randomness (per-client RNGs seeded from
one root seed), so the channels' own RNG streams — and therefore every
fault-free run — stay bit-identical with an injector attached.  The
injector is queried by `Channel.transmit` (via `link()` views), by the
gateway event loop (stalls, corruption) and by the decode scheduler
(chunk stalls); with an empty schedule every query is a no-op.

`parse_faults` turns a compact CLI spec ("blackout:0.05:0.2;burst") into
a schedule for `launch.serve --faults`.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


def _check(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


def _window_ok(t0: float, t1: float, what: str) -> None:
    _check(0.0 <= t0 < t1, f"{what}: need 0 <= t0 < t1, got [{t0}, {t1})")


@dataclasses.dataclass(frozen=True)
class Blackout:
    """All transmit attempts on the affected links are lost in [t0, t1)."""
    t0: float = 0.0
    t1: float = math.inf
    clients: "tuple[int, ...] | None" = None     # None = every client

    def __post_init__(self):
        _window_ok(self.t0, self.t1, "Blackout")


@dataclasses.dataclass(frozen=True)
class BurstLoss:
    """Gilbert–Elliott burst loss: a two-state Markov chain per link.

    The chain advances one step per transmit attempt inside the window;
    attempts drop with the current state's loss probability.  Defaults
    give ~3-attempt bursts of near-total loss on an otherwise clean link.
    """
    t0: float = 0.0
    t1: float = math.inf
    p_good_bad: float = 0.1        # P(good -> bad) per attempt
    p_bad_good: float = 0.3        # P(bad -> good) per attempt
    loss_good: float = 0.0
    loss_bad: float = 1.0
    clients: "tuple[int, ...] | None" = None

    def __post_init__(self):
        _window_ok(self.t0, self.t1, "BurstLoss")
        for name in ("p_good_bad", "p_bad_good", "loss_good", "loss_bad"):
            v = getattr(self, name)
            _check(0.0 <= v <= 1.0, f"BurstLoss.{name} must be in [0, 1], "
                                    f"got {v}")


@dataclasses.dataclass(frozen=True)
class LinkDegrade:
    """Reduced bandwidth and/or extra i.i.d. loss in [t0, t1)."""
    t0: float = 0.0
    t1: float = math.inf
    bandwidth_scale: float = 1.0   # serialization time divides by this
    extra_loss: float = 0.0        # additional i.i.d. per-attempt loss
    clients: "tuple[int, ...] | None" = None

    def __post_init__(self):
        _window_ok(self.t0, self.t1, "LinkDegrade")
        _check(self.bandwidth_scale > 0.0,
               f"LinkDegrade.bandwidth_scale must be > 0, "
               f"got {self.bandwidth_scale}")
        _check(0.0 <= self.extra_loss <= 1.0,
               f"LinkDegrade.extra_loss must be in [0, 1], "
               f"got {self.extra_loss}")


@dataclasses.dataclass(frozen=True)
class DeviceStall:
    """Extra on-device compute seconds for requests started in [t0, t1)."""
    t0: float = 0.0
    t1: float = math.inf
    stall_s: float = 0.05
    clients: "tuple[int, ...] | None" = None

    def __post_init__(self):
        _window_ok(self.t0, self.t1, "DeviceStall")
        _check(self.stall_s > 0.0,
               f"DeviceStall.stall_s must be > 0, got {self.stall_s}")


@dataclasses.dataclass(frozen=True)
class GatewayStall:
    """Extra Remote-NN service seconds for batches launched in [t0, t1):
    the feature slot pool holds its slots that much longer."""
    t0: float = 0.0
    t1: float = math.inf
    stall_s: float = 0.05

    def __post_init__(self):
        _window_ok(self.t0, self.t1, "GatewayStall")
        _check(self.stall_s > 0.0,
               f"GatewayStall.stall_s must be > 0, got {self.stall_s}")


@dataclasses.dataclass(frozen=True)
class PayloadCorruption:
    """Delivered payloads are corrupted with ``prob`` in [t0, t1): the
    LZW code stream is truncated or bit-flipped on the air.  The gateway
    detects this (`PayloadCorruptionError`) and zero-fills the request's
    offloaded channels instead of crashing or retrying."""
    t0: float = 0.0
    t1: float = math.inf
    prob: float = 1.0
    clients: "tuple[int, ...] | None" = None

    def __post_init__(self):
        _window_ok(self.t0, self.t1, "PayloadCorruption")
        _check(0.0 < self.prob <= 1.0,
               f"PayloadCorruption.prob must be in (0, 1], got {self.prob}")


@dataclasses.dataclass(frozen=True)
class ArrivalBurst:
    """Client stampede: an arrival nominally at ``t`` in [t0, t1) lands
    at ``t0 + (t - t0) / factor`` instead — the window's arrivals
    compress into its first ``1/factor``-th, so offered load inside the
    burst multiplies by ``factor`` while total demand is unchanged.
    Deterministic (no RNG): the same schedule maps the same arrival
    times on every run, which is what lets the overload benches pin
    reject/shed rates as exact rows."""
    t0: float = 0.0
    t1: float = math.inf
    factor: float = 10.0
    clients: "tuple[int, ...] | None" = None

    def __post_init__(self):
        _window_ok(self.t0, self.t1, "ArrivalBurst")
        _check(self.factor >= 1.0,
               f"ArrivalBurst.factor must be >= 1, got {self.factor}")


@dataclasses.dataclass(frozen=True)
class SlotPoolStall:
    """Decode-scheduler fault: scheduling rounds in [r0, r1) dispatch no
    decode chunk (the executor is stalled); deadlines keep aging, so
    deadline-evict — not the stall — decides when requests leave."""
    r0: int = 0
    r1: int = 1 << 30

    def __post_init__(self):
        _check(0 <= self.r0 < self.r1,
               f"SlotPoolStall: need 0 <= r0 < r1, got [{self.r0}, {self.r1})")


@dataclasses.dataclass(frozen=True)
class EngineCrash:
    """Decode-scheduler fault: the engine dies at the start of
    scheduling round ``r`` (0-based) — `ContinuousScheduler.step` raises
    `EngineCrashError`, losing the pool and every in-flight chunk.  With
    a request journal attached, `serve.recovery` reconstructs the
    frontend from the journaled events and replays the in-flight
    requests bit-identically; without one, this is the fault that proves
    work *would* be lost."""
    r: int = 0

    def __post_init__(self):
        _check(self.r >= 0, f"EngineCrash: need r >= 0, got {self.r}")


class EngineCrashError(RuntimeError):
    """The scripted `EngineCrash` fired: the scheduler's state is gone.
    Callers holding a journal hand it to `serve.recovery.recover`."""


FaultEvent = (Blackout, BurstLoss, LinkDegrade, DeviceStall, GatewayStall,
              PayloadCorruption, ArrivalBurst, SlotPoolStall, EngineCrash)


def _applies(ev, client: int) -> bool:
    return ev.clients is None or client in ev.clients


class _GEChain:
    """One link's Gilbert–Elliott state, advanced per transmit attempt."""

    def __init__(self, spec: BurstLoss, rng: np.random.RandomState):
        self.spec = spec
        self.rng = rng
        self.bad = False

    def attempt_lost(self) -> bool:
        s = self.spec
        flip = float(self.rng.uniform())
        if self.bad:
            self.bad = flip >= s.p_bad_good
        else:
            self.bad = flip < s.p_good_bad
        loss = s.loss_bad if self.bad else s.loss_good
        if loss <= 0.0:
            return False
        if loss >= 1.0:
            return True
        return float(self.rng.uniform()) < loss


class LinkFaultView:
    """Per-client view handed to `Channel.transmit`: answers, for one
    attempt at simulated time t, whether the attempt is force-lost and
    how much the link's bandwidth is scaled.  All randomness comes from
    the injector's per-client RNG, never the channel's own stream."""

    def __init__(self, injector: "FaultInjector", client: int):
        self._inj = injector
        self.client = client

    def bandwidth_scale(self, t: float) -> float:
        scale = 1.0
        for ev in self._inj.degrades:
            if _applies(ev, self.client) and ev.t0 <= t < ev.t1:
                scale *= ev.bandwidth_scale
        return scale

    def attempt_lost(self, t: float) -> bool:
        inj, c = self._inj, self.client
        for ev in inj.blackouts:
            if _applies(ev, c) and ev.t0 <= t < ev.t1:
                return True
        lost = False
        for ev, chain in inj.chains_for(c):
            if ev.t0 <= t < ev.t1 and chain.attempt_lost():
                lost = True              # chain still advances when another
        if lost:                         # event already lost the attempt
            return True
        rng = inj.rng_for(c)
        for ev in inj.degrades:
            if (_applies(ev, c) and ev.t0 <= t < ev.t1 and ev.extra_loss > 0
                    and float(rng.uniform()) < ev.extra_loss):
                return True
        return False


class FaultInjector:
    """A seeded fault schedule queried by every layer of the stack.

    The same (schedule, seed) pair replays the exact same fault decisions
    on every run — fault randomness is isolated per client, so one
    client's retries never perturb another's loss sequence."""

    def __init__(self, schedule: "tuple | list" = (), *, seed: int = 0):
        events = tuple(schedule)
        for ev in events:
            _check(isinstance(ev, FaultEvent),
                   f"unknown fault event {type(ev).__name__}")
        self.schedule = events
        self.seed = seed
        self.blackouts = tuple(e for e in events if isinstance(e, Blackout))
        self.bursts = tuple(e for e in events if isinstance(e, BurstLoss))
        self.degrades = tuple(e for e in events if isinstance(e, LinkDegrade))
        self.dev_stalls = tuple(e for e in events
                                if isinstance(e, DeviceStall))
        self.gw_stalls = tuple(e for e in events
                               if isinstance(e, GatewayStall))
        self.corruptions = tuple(e for e in events
                                 if isinstance(e, PayloadCorruption))
        self.pool_stalls = tuple(e for e in events
                                 if isinstance(e, SlotPoolStall))
        self.crashes = tuple(e for e in events
                             if isinstance(e, EngineCrash))
        self.arrival_bursts = tuple(e for e in events
                                    if isinstance(e, ArrivalBurst))
        self._rngs: dict[int, np.random.RandomState] = {}
        self._chains: dict[int, list] = {}
        self._views: dict[int, LinkFaultView] = {}

    # ------------------------------------------------------------ state --
    def rng_for(self, client: int) -> np.random.RandomState:
        rng = self._rngs.get(client)
        if rng is None:
            rng = self._rngs[client] = np.random.RandomState(
                (self.seed * 1_000_003 + 9_176 * client + 7) % (1 << 31))
        return rng

    def chains_for(self, client: int) -> list:
        chains = self._chains.get(client)
        if chains is None:
            chains = self._chains[client] = [
                (ev, _GEChain(ev, self.rng_for(client)))
                for ev in self.bursts if _applies(ev, client)]
        return chains

    def link(self, client: int) -> LinkFaultView:
        view = self._views.get(client)
        if view is None:
            view = self._views[client] = LinkFaultView(self, client)
        return view

    # ----------------------------------------------------------- stalls --
    def device_stall_extra(self, client: int, t: float) -> float:
        return sum(ev.stall_s for ev in self.dev_stalls
                   if _applies(ev, client) and ev.t0 <= t < ev.t1)

    def server_stall_extra(self, t: float) -> float:
        return sum(ev.stall_s for ev in self.gw_stalls if ev.t0 <= t < ev.t1)

    def chunk_stalled(self, round_idx: int) -> bool:
        return any(ev.r0 <= round_idx < ev.r1 for ev in self.pool_stalls)

    def crashed(self, round_idx: int) -> bool:
        return any(ev.r == round_idx for ev in self.crashes)

    # --------------------------------------------------------- arrivals --
    def arrival_time(self, client: int, t: float) -> float:
        """Map one nominal arrival time through the stampede schedule:
        arrivals inside an `ArrivalBurst` window compress toward its
        start by the burst factor; everything else passes through
        unchanged (so an empty schedule is exactly the identity)."""
        for ev in self.arrival_bursts:
            if _applies(ev, client) and ev.t0 <= t < ev.t1:
                return ev.t0 + (t - ev.t0) / ev.factor
        return t

    # ------------------------------------------------------- corruption --
    def corrupt(self, client: int, t: float, codes: list) -> "list | None":
        """A corrupted copy of a payload's LZW code stream, or None when
        no corruption event fires.  Truncation drops a suffix; flips xor
        a random bit into one code — typically caught by the hardened
        decoder or the framing length check (a flip that lands on
        another valid code is undetectable without checksums and serves
        a garbled frame, like a real radio would)."""
        for ev in self.corruptions:
            if not (_applies(ev, client) and ev.t0 <= t < ev.t1):
                continue
            rng = self.rng_for(client)
            if float(rng.uniform()) >= ev.prob:
                continue
            bad = list(codes)
            if not bad:
                return bad
            if int(rng.randint(2)) or len(bad) == 1:
                i = int(rng.randint(len(bad)))
                bad[i] = int(bad[i]) ^ (1 << int(rng.randint(14)))
            else:
                bad = bad[:int(rng.randint(1, len(bad)))]
            return bad
        return None


def parse_faults(spec: str) -> tuple:
    """Compact CLI fault schedule: ';'-separated events, ':'-separated
    fields (times in seconds of simulated time).

      blackout[:t0:t1]         link dark in [t0, t1)      (default whole run)
      burst[:t0:t1[:pgb:pbg]]  Gilbert–Elliott burst loss
      degrade[:t0:t1[:scale[:loss]]]   bandwidth scale + extra loss
      devstall[:t0:t1[:s]]     extra device compute seconds
      gwstall[:t0:t1[:s]]      extra gateway service seconds
      corrupt[:t0:t1[:p]]      payload corruption probability
      stampede[:t0:t1[:f]]     client stampede: the window's arrivals
                               compress toward t0 by factor f (offered
                               load x f inside the burst)

    e.g. --faults "blackout:0.05:0.2;burst;corrupt:0:1:0.3"
    """
    out = []
    for item in spec.split(";"):
        item = item.strip()
        if not item:
            continue
        kind, *fs = item.split(":")
        f = [float(x) for x in fs]
        window = {"t0": f[0], "t1": f[1]} if len(f) >= 2 else {}
        if kind == "blackout":
            out.append(Blackout(**window))
        elif kind == "burst":
            extra = ({"p_good_bad": f[2], "p_bad_good": f[3]}
                     if len(f) >= 4 else {})
            out.append(BurstLoss(**window, **extra))
        elif kind == "degrade":
            extra = {"bandwidth_scale": f[2]} if len(f) >= 3 else {}
            if len(f) >= 4:
                extra["extra_loss"] = f[3]
            out.append(LinkDegrade(**window, **extra))
        elif kind == "devstall":
            extra = {"stall_s": f[2]} if len(f) >= 3 else {}
            out.append(DeviceStall(**window, **extra))
        elif kind == "gwstall":
            extra = {"stall_s": f[2]} if len(f) >= 3 else {}
            out.append(GatewayStall(**window, **extra))
        elif kind == "corrupt":
            extra = {"prob": f[2]} if len(f) >= 3 else {}
            out.append(PayloadCorruption(**window, **extra))
        elif kind == "stampede":
            extra = {"factor": f[2]} if len(f) >= 3 else {}
            out.append(ArrivalBurst(**window, **extra))
        else:
            raise ValueError(f"unknown fault kind {kind!r} in --faults spec")
    return tuple(out)
