"""Continuous-batching scheduler: bucketed prefill + paged slot-pool decode.

The unit of work is a `Request` (see `repro.serve.engine`).  Admission
right-pads each prompt to the smallest configured length bucket, runs one
prefill per group (compiled once per bucket), and *injects* the resulting
rows into free slots of a fixed-width decode pool.  Decoding runs in
chunked `lax.while_loop` segments over the whole pool: per-slot EOS ids,
token budgets, and sampling temperatures all live in-graph, so one
compiled program serves every mix of requests.  Between segments the host
*evicts* finished slots (one small device->host copy of the token buffer)
and admits queued requests into the freed slots — the loop never
recompiles and never drains.

The slot-pool KV cache is *page granular*: prefill returns rows at the
bucket's page-rounded width (`page_size`) instead of the full pool width,
so injecting a request copies only the pages its prompt covers — slots
keep whatever stale keys the previous occupant left past that point, and
decode masks them out by depth (a cache slot only becomes attendable the
step its row writes it).  `decode_step`'s attention visits only the KV
pages below the pool's deepest live row (`repro.kernels.decode_attention`),
so a wide pool costs what its occupancy costs, not its capacity.

Long prompts admit through *chunked prefill*: a prompt whose bucket
exceeds `prefill_segment` is staged one segment at a time between decode
chunks (`backbone.prefill_chunk`, bit-identical to one-shot prefill), so
a long admission can never stall the decode pool for more than one
segment of prefill work.  One admission stages at a time; short groups
keep admitting around it, and the staged slot joins the pool when its
last segment lands.

The pool is *mesh-shardable*: given a ``mesh`` (see
`launch.mesh.make_serving_mesh`), the slot axis of every pool leaf shards
over the data axes via `NamedSharding` (`launch.partition.pool_shardings`)
and params go tensor-parallel through the serving partition rules — the
same compiled programs run SPMD across the mesh, host-side evict/inject
addresses slots whose rows live wholly on one data shard, and greedy
outputs stay bit-identical to the single-device pool (tested on a forced
multi-device CPU mesh).

With ``SchedulerConfig.overlap`` (the default) the host pipelines itself
one round deep against the device: while round k-1's decode chunk is
still in flight, round k's staged prefill segment dispatches and its
admission groups are bucketed/tokenized and injected — no host sync
between them, JAX async dispatch queues it all behind the chunk.  Only
then does the host block on round k-1's done flags (whose device->host
copy started at dispatch, so the read usually lands instantly), evict,
admit into the freed slots, and dispatch round k's chunk.  A long
admission's prefill segments therefore overlap decode instead of taking
turns with it, and the device never idles while the host tokenizes.
Evict/admit timing is round-identical to ``overlap=False`` (admit,
decode, block on the drain every round — the A/B baseline); completions
just report one round later.

Correctness invariants (tested against one-request-at-a-time decode):
  * pad keys are masked out of prefill attention and pad/stale cache
    slots are overwritten by decode writes before they become
    attendable, so neither bucket padding nor page-granular injects can
    change a request's tokens;
  * batch rows are independent end-to-end, so evict/inject of one slot
    preserves every other slot's cache contents bit-for-bit — which is
    also why overlap's one-round-late eviction cannot move a token: a
    done row is masked out of decode in-graph until it is drained.

The padded-prefill path needs per-row attention masking and per-row cache
depths, so the scheduler serves attention-only token models (no recurrent
state to pollute with pads, no MoE capacity for pads to compete over);
`supports_continuous_batching` gates it and `ServeEngine` falls back to
equal-length grouping elsewhere.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from contextlib import nullcontext
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.kernels.common import round_up
from repro.models import backbone as bb
from repro.serve import telemetry as _telemetry

_NULL = nullcontext()     # reentrant: shared no-op for disabled telemetry


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    buckets: tuple[int, ...] = (8, 16, 32, 64, 128)
    max_slots: int = 8         # decode pool width (concurrent requests)
    prefill_group: int = 4     # fixed prefill batch (bounds compile count)
    chunk: int = 8             # decode steps per while_loop segment
    page_size: int = 32        # KV copy granularity: injects move
                               # ceil(bucket / page_size) pages, not the
                               # full pool-width strip
    prefill_segment: int = 64  # buckets above this prefill in segments of
                               # this many tokens, interleaved with decode
                               # chunks (0 disables chunked prefill)
    overlap: bool = True       # pipeline host scheduling against the
                               # in-flight decode chunk: drain one round
                               # behind, prepare admissions while the
                               # device runs (False: serialized rounds)
    prefix_cache: bool = False  # share prompt-prefix KV pages across
                                # admissions (serve.prefix_cache): hits
                                # seed resident pages and prefill only
                                # the suffix
    prefix_hot_pages: int = 512  # device-resident page budget; pages a
                                 # live slot references are pinned past it
    kv_tier_mb: float = 0.0    # host cold-tier budget for demoted pages,
                               # quantize+bit-pack compressed (0: demoted
                               # pages drop instead — bit-exact, no reuse
                               # after demotion)
    kv_tier_bits: int = 8      # cold-tier codebook bits per element
    preempt: bool = False      # allow the streaming frontend to suspend
                               # pooled rows mid-decode (suspend/resume
                               # preserves partial tokens; resumed greedy
                               # output is bit-identical to uninterrupted)


def supports_continuous_batching(cfg: ArchConfig) -> bool:
    """Bucketed prefill + slot-pool decode needs a pure-attention decoder:
    recurrent layers would integrate pad tokens into their state, MoE
    capacity would let pads evict real tokens, absolute sinusoidal
    positions are scalar-offset only, and SWA ring compaction could drop
    real tokens behind the pads."""
    return (cfg.hybrid is None and cfg.xlstm is None and cfg.encdec is None
            and cfg.vlm is None and cfg.moe is None and cfg.rope_theta > 0
            and cfg.sliding_window == 0)


def sample_tokens(logits, temps, key):
    """Per-request sampling, in-graph: rows with temp <= 0 take argmax
    (bit-identical to a pure-greedy program), others draw categorically at
    their own temperature."""
    greedy_t = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / jnp.maximum(temps, 1e-6)[..., None]
    drawn = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
    return jnp.where(temps <= 0.0, greedy_t, drawn)


class SlotError(RuntimeError):
    """Slot-pool misuse: acquiring an occupied slot, releasing a free
    slot (double release), or releasing a slot on behalf of a request
    that does not own it.  Preemption makes these real hazards — a
    suspend races admission for the slot it frees — so the pool fails
    loudly instead of silently corrupting occupancy."""


class SlotPool:
    """Host-side bookkeeping for a fixed set of batch slots.

    ``rids[i]`` is the request occupying slot i (None = free).  The
    continuous scheduler (decode slots) and the offload gateway
    (remote-NN feature slots) share this discipline: work is admitted
    into free slots, one fixed-shape device program runs over the whole
    pool, and slots are released as requests finish — the compiled batch
    shape never changes."""

    def __init__(self, n_slots: int):
        self.rids: list = [None] * n_slots

    def __len__(self) -> int:
        return len(self.rids)

    def free(self) -> list[int]:
        return [i for i, r in enumerate(self.rids) if r is None]

    def acquire(self, slot: int, rid) -> None:
        if self.rids[slot] is not None:
            raise SlotError(f"slot {slot} already occupied "
                            f"by {self.rids[slot]!r}")
        self.rids[slot] = rid

    def release(self, slot: int, rid=None):
        """Free a slot and return its occupant.  A free slot raises
        (double release); passing ``rid`` asserts the expected occupant,
        so a preempting caller can never free a slot that was already
        re-admitted under a fresher request."""
        cur = self.rids[slot]
        if cur is None:
            raise SlotError(f"slot {slot} released twice (already free)")
        if rid is not None and cur != rid:
            raise SlotError(f"slot {slot} is owned by {cur!r}, "
                            f"not {rid!r}")
        self.rids[slot] = None
        return cur

    def occupied(self) -> list[tuple[int, object]]:
        return [(i, r) for i, r in enumerate(self.rids) if r is not None]

    def any_occupied(self) -> bool:
        return any(r is not None for r in self.rids)


@dataclasses.dataclass
class Suspended:
    """A request evicted mid-decode with its progress preserved.

    `request` is the request as originally submitted (prompt and full
    token budget); `generated` holds every token decoded before the
    suspension.  `submit_suspended` re-admits it through the ordinary
    prefill path — prompt + generated prefill as one longer prompt and
    the remaining budget decodes from there, so greedy output is
    bit-identical to an uninterrupted run.  `parked` (when the prefix
    cache is on) is the handle keeping the slot's pinned pages resident
    while the request waits to resume."""
    request: object
    generated: np.ndarray                  # (g,) int32 tokens so far
    deadline_at: Optional[float] = None    # absolute clock() deadline
    parked: Optional[object] = None        # PrefixCache.park handle


class ContinuousScheduler:
    """Drives a decode slot pool over an unbounded request queue.

    submit() enqueues and returns a request id; run() drains the queue and
    returns {rid: Completion}; step() advances one admit+decode segment
    (benchmarks interleave Poisson arrivals between steps).
    """

    def __init__(self, cfg: ArchConfig, params, *,
                 sched: Optional[SchedulerConfig] = None,
                 max_len: int = 256, seed: int = 0, mesh=None,
                 clock=None, faults=None, telemetry=None):
        """clock: wall-time source for request deadlines (default
        `time.monotonic`; tests inject a fake for determinism).
        faults: a `repro.serve.faults.FaultInjector` whose
        `chunk_stalled(round)` stalls decode rounds — requests then leave
        through deadline eviction instead of hanging the drain loop.
        telemetry: a `repro.serve.telemetry.Telemetry`; the module
        default is disabled, and every hook below guards on
        `tel.enabled`, so an uninstrumented run does zero extra clock
        reads or device->host copies (telemetry never reads `clock` —
        injected test clocks advance on every read)."""
        assert supports_continuous_batching(cfg), \
            f"{cfg.name}: continuous batching needs a pure-attention " \
            "RoPE decoder (use ServeEngine's equal-length grouping)"
        self.cfg = cfg
        self.params = params
        self.sched = sched or SchedulerConfig()
        self.max_len = max_len
        self.mesh = mesh
        self.faults = faults
        self.tel = telemetry if telemetry is not None else _telemetry.default()
        self._clock = clock if clock is not None else time.monotonic
        self._deadlines: dict[int, float] = {}   # rid -> absolute clock()
        self._round = 0
        self._key = jax.random.PRNGKey(seed)
        S = self.sched.max_slots
        L = max_len
        # the pool's KV width is a power-of-two page count so decode
        # attention always has a paged cache with a *dense* divisor
        # ladder to early-exit over (a raw max_len like 152 would round
        # to 160, whose only ladder widths are 32 and 160 — one deep row
        # would force full-width attention); <2x memory, and requests
        # still budget against max_len
        page = self.sched.page_size
        n_pages = 1 << max(1, (round_up(max_len, page) // page - 1)
                           .bit_length())
        self._kv_len = page * n_pages
        cache = bb.init_cache(cfg, S, self._kv_len)
        assert set(cache) == {"k", "v"}, sorted(cache)
        self._pool = {
            "buf": jnp.zeros((S, L), jnp.int32),
            "gen": jnp.zeros((S,), jnp.int32),
            "done": jnp.ones((S,), bool),
            "tok": jnp.zeros((S, 1), jnp.int32),
            "cache": cache,
            "cache_len": jnp.zeros((S,), jnp.int32),
            "eos": jnp.full((S,), -1, jnp.int32),
            "max_new": jnp.ones((S,), jnp.int32),
            "temps": jnp.zeros((S,), jnp.float32),
        }
        if mesh is not None:
            from repro.launch.mesh import axis_size, data_axes
            from repro.launch.partition import param_shardings, pool_shardings
            dsize = axis_size(mesh, data_axes(mesh))
            assert S % dsize == 0, \
                f"max_slots {S} must divide the {dsize}-way data axes so " \
                "every data shard owns a fixed strip of slots"
            # serving params are tensor-parallel only (weights resident on
            # the model axis, no FSDP gathers in the token loop); the pool
            # shards its slot axis over the data axes, so each device
            # decodes its own strip of slots with the same compiled program
            self.params = params = jax.device_put(
                params, param_shardings(params, mesh))
            self._pool = jax.device_put(
                self._pool, pool_shardings(self._pool, mesh))
        self._slots = SlotPool(S)
        self._queue: deque = deque()           # (rid, Request)
        self._staging: list[dict] = []         # chunked-prefill admissions
        self._results: dict[int, object] = {}
        self._next_rid = 0
        # suspend/resume bookkeeping: the request as submitted (so a
        # suspension can reconstruct the original prompt/budget), the
        # already-generated prefix a resumed rid must prepend to every
        # stream/Completion, and the parked prefix-pin handle to drop
        # once the resumed rid is re-pinned at admission
        self._req_of: dict[int, object] = {}
        self._resume: dict[int, np.ndarray] = {}
        self._parked_tok: dict[int, object] = {}
        self._pending: Optional[dict] = None   # in-flight chunk snapshot
        # streaming hook (serve.frontend): called between rounds with
        # (rid, tokens_so_far) for every live pooled request — overlap
        # rounds publish from the drained chunk's snapshot, serialized
        # rounds from the pool, so tokens stream as each chunk lands.
        # None (the default) skips the per-round buf/gen host copies
        # entirely: a non-streaming run does no extra device->host work
        self.stream_cb: Optional[object] = None
        self.prefix = None
        if self.sched.prefix_cache:
            from repro.serve.prefix_cache import PrefixCache
            self.prefix = PrefixCache(
                page, hot_pages=self.sched.prefix_hot_pages,
                cold_bytes=int(self.sched.kv_tier_mb * (1 << 20)),
                bits=self.sched.kv_tier_bits)

        def _prefill(params, tokens, lengths, *, max_len):
            return bb.prefill(cfg, params, {"tokens": tokens},
                              max_len=max_len, lengths=lengths)

        self._prefill = jax.jit(_prefill,      # compiles once per bucket
                                static_argnames=("max_len",))
        self._prefill_chunk = jax.jit(         # compiles once per bucket
            partial(bb.prefill_chunk, cfg),
            static_argnames=("attend_width",))
        self._inject = jax.jit(self._inject_impl)
        donate = (1,) if jax.default_backend() == "tpu" else ()
        self._chunk = jax.jit(self._chunk_impl, donate_argnums=donate)

    # ------------------------------------------------------------- device --

    def _inject_impl(self, pool, slots, rows, logits0, prompt_lens, eos,
                     max_new, temps, key):
        """Seed freshly prefilled requests into pool slots, in-graph.

        slots: (G,) target slot per group row; dummy rows (group padding)
        carry slot == max_slots and are dropped by the scatters.  The
        first token of each request is sampled here from the prefill
        logits, mirroring the equal-length engine loop.

        rows arrive at the bucket's page-rounded width, so the cache
        scatter copies only the pages the prompt covers; whatever the
        slot's previous occupant left past that width stays in place and
        is masked out of attention until a decode write overtakes it.
        """
        S, L = pool["buf"].shape
        tok0 = sample_tokens(logits0, temps, key)
        row0 = jnp.zeros((slots.shape[0], L), jnp.int32).at[:, 0].set(tok0)
        new = dict(pool)
        new["buf"] = pool["buf"].at[slots].set(row0, mode="drop")
        new["gen"] = pool["gen"].at[slots].set(1, mode="drop")
        new["done"] = pool["done"].at[slots].set(
            (tok0 == eos) | (max_new <= 1), mode="drop")
        new["tok"] = pool["tok"].at[slots].set(tok0[:, None], mode="drop")

        def put_pages(leaf, r):
            W = min(leaf.shape[3], r.shape[3])   # KV-axis capacities
            return leaf.at[:, :, slots, :W].set(
                r[:, :, :, :W].astype(leaf.dtype), mode="drop")

        new["cache"] = jax.tree.map(put_pages, pool["cache"], rows)
        new["cache_len"] = pool["cache_len"].at[slots].set(
            prompt_lens, mode="drop")
        new["eos"] = pool["eos"].at[slots].set(eos, mode="drop")
        new["max_new"] = pool["max_new"].at[slots].set(max_new, mode="drop")
        new["temps"] = pool["temps"].at[slots].set(temps, mode="drop")
        return new

    def _chunk_impl(self, params, pool, active, key, n_steps):
        """Up to n_steps decode steps over the whole pool as one
        while_loop; exits early when every occupied slot is done.
        n_steps is traced, so segment length never recompiles."""
        S, L = pool["buf"].shape

        def cond(state):
            step, pool, _ = state
            return (step < n_steps) & jnp.any(active & ~pool["done"])

        def body(state):
            step, pool, key = state
            logits, cache = bb.decode_step(self.cfg, params, pool["tok"],
                                           pool["cache"], pool["cache_len"])
            key, sub = jax.random.split(key)
            t = sample_tokens(logits, pool["temps"], sub)
            run = active & ~pool["done"]
            pos = jnp.where(run, pool["gen"], L)     # OOB rows -> dropped
            buf = pool["buf"].at[jnp.arange(S), pos].set(t, mode="drop")
            gen = pool["gen"] + run.astype(jnp.int32)
            done = pool["done"] | (run & ((t == pool["eos"])
                                          | (gen >= pool["max_new"])))
            # only running rows advance their depth: done/free slots keep
            # cache_len frozen (and evict resets it), so the paged decode
            # kernel's max-depth branch tracks live occupancy, not the
            # deepest slot the pool has ever held
            new = dict(pool, buf=buf, gen=gen, done=done, cache=cache,
                       tok=jnp.where(run[:, None], t[:, None], pool["tok"]),
                       cache_len=pool["cache_len"] + run.astype(jnp.int32))
            return step + 1, new, key

        _, pool, key = jax.lax.while_loop(
            cond, body, (jnp.zeros((), jnp.int32), pool, key))
        return pool, key

    # --------------------------------------------------------------- host --

    def _span(self, name: str):
        """Wall span on the scheduler track; shared no-op when telemetry
        is disabled (no clock read, no allocation)."""
        if not self.tel.enabled:
            return _NULL
        return self.tel.span(name, track="scheduler", cat="sched",
                             round=self._round)

    def export_metrics(self) -> None:
        """Refresh per-round gauges, compile counters, and the re-export
        of the prefix cache's `stats` dict into the registry.  Called at
        the end of every round while telemetry is enabled (and by the
        launcher before the final dump)."""
        tel = self.tel
        if not tel.enabled:
            return
        m = tel.metrics
        m.gauge("sched.pool_occupancy").set(
            sum(r is not None for r in self._slots.rids))
        m.gauge("sched.backlog").set(self.backlog())
        m.gauge("sched.staging").set(len(self._staging))
        tel.note_compiles("sched.decode_chunk", self._chunk,
                          shape=f"slots{len(self._slots)}")
        tel.note_compiles("sched.inject", self._inject,
                          shape=f"slots{len(self._slots)}")
        if self.prefix is not None:
            for k, v in self.prefix.stats.items():
                m.gauge(f"prefix.{k}").set(v)
            m.gauge("prefix.hit_rate").set(self.prefix.hit_rate)
            m.gauge("prefix.hot_pages").set(self.prefix.n_hot)
            m.gauge("prefix.cold_pages").set(self.prefix.n_cold)
            m.gauge("prefix.cold_used_bytes").set(
                self.prefix.cold_used_bytes)

    def _bucket_of(self, prompt_len: int) -> int:
        fits = [b for b in self.sched.buckets
                if prompt_len <= b <= self.max_len]
        if fits:
            return min(fits)
        # a prompt above every configured bucket still buckets at page
        # granularity: returning the raw length would compile a fresh
        # prefill per distinct long-prompt length
        return min(round_up(prompt_len, self.sched.page_size), self.max_len)

    def submit(self, request, *, deadline_at=None) -> int:
        """deadline_at: absolute deadline on this scheduler's clock()
        timeline, overriding request.deadline_s — used by the streaming
        frontend, which fixes deadlines at admission time rather than at
        the (later) instant the feeder releases the request."""
        T = len(request.tokens)
        assert T >= 1, "empty prompt"
        assert request.max_new_tokens >= 1, "max_new_tokens must be >= 1"
        bucket = self._bucket_of(T)
        assert max(bucket, T + request.max_new_tokens) <= self.max_len, \
            f"prompt {T} (+{request.max_new_tokens} new, bucket {bucket}) " \
            f"exceeds scheduler max_len {self.max_len}"
        assert request.extras is None, \
            "the continuous scheduler serves token-only requests"
        rid = self._next_rid
        self._next_rid += 1
        if deadline_at is not None:
            self._deadlines[rid] = float(deadline_at)
        elif getattr(request, "deadline_s", None) is not None:
            assert request.deadline_s > 0, "deadline_s must be > 0"
            self._deadlines[rid] = self._clock() + request.deadline_s
        self._req_of[rid] = request
        self._queue.append((rid, request))
        return rid

    @property
    def _slot_rid(self) -> list:
        """Slot occupancy (kept as the historical attribute name: the
        steady-state benchmark polls it between steps)."""
        return self._slots.rids

    def backlog(self) -> int:
        """Requests admitted but not yet pooled (queued + staging) — the
        depth a frontend's feeder meters against."""
        return len(self._queue) + len(self._staging)

    def has_work(self) -> bool:
        """True while anything is queued, staging, or pooled."""
        return bool(self._queue or self._staging
                    or self._slots.any_occupied())

    def pop_completion(self, rid: int):
        """Remove and return one finished request's Completion.  The
        streaming frontend collects completions round by round from
        `step()`'s return value; `run()` keeps its collect-everything
        semantics for batch callers."""
        return self._results.pop(rid)

    # ------------------------------------------------ suspend / resume --

    def suspend(self, rid: int) -> Optional[Suspended]:
        """Evict a pooled request mid-decode, preserving its progress.

        Returns None when the row has in fact already finished (its
        Completion drains normally next round — the caller should pick
        another victim).  Reading the pool blocks on the in-flight chunk
        in overlap mode, so the suspension captures every token decoded
        so far; the pending snapshot's same-occupant eligibility guard
        then skips the released slot, exactly as it does for any slot
        freed and re-admitted between a dispatch and its drain.  Pinned
        prefix pages are parked (refs held) so a prompt resume can still
        seed them; the pages are released when the resumed admission
        re-pins, or when the suspension is discarded."""
        slot = next((i for i, r in enumerate(self._slot_rid) if r == rid),
                    None)
        assert slot is not None and slot not in self._staging_slots(), \
            f"rid {rid} is not pooled (queued/staging rows cannot suspend)"
        buf = np.asarray(self._pool["buf"])
        gen = np.asarray(self._pool["gen"])
        if np.asarray(self._pool["done"])[slot]:
            return None
        toks = buf[slot, :gen[slot]].astype(np.int32)
        prefix = self._resume.pop(rid, None)
        if prefix is not None:
            toks = np.concatenate([prefix, toks])
        n_pre = 0 if prefix is None else len(prefix)
        sub = self._req_of.pop(rid)
        # undo a previous resume's prompt extension: the Suspended record
        # always carries the *original* request plus all tokens so far
        orig = dataclasses.replace(
            sub,
            tokens=np.asarray(sub.tokens, np.int32)[:len(sub.tokens) - n_pre],
            max_new_tokens=sub.max_new_tokens + n_pre, deadline_s=None)
        parked = None
        if self.prefix is not None:
            parked = self.prefix.park(slot, ("suspend", rid))
        self._unpark(rid)                      # resumed-but-never-admitted
        self._slots.release(slot, rid)
        self._pool["cache_len"] = self._pool["cache_len"].at[slot].set(0)
        deadline_at = self._deadlines.pop(rid, None)
        if self.tel.enabled:
            self.tel.counter("sched.evicted", reason="preempted").inc()
        return Suspended(orig, toks, deadline_at, parked)

    def submit_suspended(self, sus: Suspended, *, deadline_at=None) -> int:
        """Re-admit a suspended request through the ordinary prefill
        path: prompt + generated-so-far tokens prefill as one longer
        prompt (chunked prefill and prefix-page seeding apply as for any
        admission), the next token samples from the resumed prefill's
        logits, and streams/Completion carry the full token sequence.
        Greedy rows are bit-identical to an uninterrupted run: argmax
        sampling is RNG-free and prefill is bit-identical however the
        prompt is segmented.  Returns the new rid."""
        req = sus.request
        gen = np.asarray(sus.generated, np.int32)
        remaining = req.max_new_tokens - len(gen)
        assert remaining >= 1, \
            "suspended request has exhausted its token budget"
        cont = dataclasses.replace(
            req, tokens=np.concatenate([np.asarray(req.tokens, np.int32),
                                        gen]),
            max_new_tokens=remaining, deadline_s=None)
        if deadline_at is None:
            deadline_at = sus.deadline_at
        rid = self.submit(cont, deadline_at=deadline_at)
        if len(gen):
            self._resume[rid] = gen
        if sus.parked is not None:
            self._parked_tok[rid] = sus.parked
        if self.tel.enabled:
            self.tel.counter("sched.resumed").inc()
        return rid

    def discard_suspended(self, sus: Suspended) -> None:
        """Drop a suspension that will never resume (its frontend shed
        it): release the parked prefix pins.  The generated tokens live
        in the Suspended record — the caller resolves the request with
        them, so nothing is silently lost."""
        if self.prefix is not None and sus.parked is not None:
            self.prefix.unpark(sus.parked)

    def _unpark(self, rid: int) -> None:
        """Drop the parked pins a resumed rid carried, once its new
        admission has pinned (or once it resolves without admitting)."""
        tok = self._parked_tok.pop(rid, None)
        if tok is not None and self.prefix is not None:
            self.prefix.unpark(tok)

    def _resume_prefix(self, rid) -> Optional[np.ndarray]:
        return self._resume.get(rid)

    def _free_slots(self) -> list[int]:
        return self._slots.free()

    def _staging_slots(self) -> set:
        return {st["slot"] for st in self._staging}

    def _copy_width(self, bucket: int) -> int:
        """Token width of the cache rows an admission copies into the
        pool: the bucket rounded up to whole pages (never the full pool
        width)."""
        return min(self._kv_len, round_up(bucket, self.sched.page_size))

    def _is_long(self, req) -> bool:
        seg = self.sched.prefill_segment
        return bool(seg) and self._bucket_of(len(req.tokens)) > seg

    def _has_hit(self, req) -> bool:
        """True when the request's leading pages are resident: it will
        admit through a prefix plan when it leads, so group formation
        skips it (a group row would re-prefill the prefix)."""
        return (self.prefix is not None
                and self.prefix.lookup(req.tokens)[1] > 0)

    def _plan_one(self):
        """Form one admission decision from the queue head: a bucket
        group (returned as a prepared dict of numpy prefill inputs, its
        slots acquired), a staging claim (returns True), or None when
        nothing can admit.  Pure host work — the device is untouched, so
        overlap mode runs this while a decode chunk is in flight.

        Groups are formed in FIFO order keyed by the head request's
        bucket, so the queue head is always in the next group — no
        request can be starved by a stream of other-bucket arrivals.  A
        long head (bucket > prefill_segment) claims a slot and stages
        instead; while a staging is already in flight the head's wait is
        bounded by its remaining segments, and the first short group
        behind it keeps the pool fed.

        With the prefix cache on, a short lead whose leading pages are
        resident leads a *prefix plan* (seed the pages, prefill only the
        suffixes) batched with queued requests sharing its bucket and
        hit depth, and ordinary groups are formed from hit-free requests
        only; a hit-carrying request that can't join just waits to lead,
        which FIFO bounds the same way it bounds buckets.
        """
        free = self._free_slots()
        if not free or not self._queue:
            return None
        head_rid, head_req = self._queue[0]
        if self._is_long(head_req):
            if not self._staging:
                self._queue.popleft()
                self._start_staging(head_rid, head_req, free[0])
                return True
            shorts = [(r, q) for r, q in self._queue
                      if not self._is_long(q)]
            if not shorts:
                return None
            lead_rid, lead_req = shorts[0]
        else:
            lead_rid, lead_req = head_rid, head_req
        if self.prefix is not None:
            n_hit = self.prefix.lookup(lead_req.tokens)[1]
            if n_hit:
                return self._plan_prefix_group(lead_req, free, n_hit)
        head_bucket = self._bucket_of(len(lead_req.tokens))

        G = self.sched.prefill_group
        take, keep = [], deque()
        for rid, req in self._queue:
            if (len(take) < min(len(free), G) and not self._is_long(req)
                    and self._bucket_of(len(req.tokens)) == head_bucket
                    and not self._has_hit(req)):
                take.append((rid, req))
            else:
                keep.append((rid, req))
        if not take:
            return None
        self._queue = keep

        tokens = np.zeros((G, head_bucket), np.int32)
        lengths = np.ones((G,), np.int32)        # dummies: 1 valid token
        slots = np.full((G,), self.sched.max_slots, np.int32)
        eos = np.full((G,), -1, np.int32)
        max_new = np.ones((G,), np.int32)
        temps = np.zeros((G,), np.float32)
        pkeys = []
        for g, ((rid, req), slot) in enumerate(zip(take, free)):
            T = len(req.tokens)
            tokens[g, :T] = np.asarray(req.tokens, np.int32)
            lengths[g] = T
            slots[g] = slot
            eos[g] = req.eos_id
            max_new[g] = req.max_new_tokens
            temps[g] = req.temperature
            self._slots.acquire(slot, rid)
            if self.prefix is not None:
                pkeys.append(self.prefix.lookup(req.tokens)[0])
        return {"bucket": head_bucket, "tokens": tokens, "lengths": lengths,
                "slots": slots, "eos": eos, "max_new": max_new,
                "temps": temps, "pkeys": pkeys,
                "rids": [rid for rid, _ in take]}

    def _plan_prefix_group(self, lead_req, free: list[int],
                           n_hit: int) -> Optional[dict]:
        """Form one batched prefix-hit admission: up to prefill_group
        short requests sharing the lead's bucket AND resident-page depth
        (their seeded widths — and so the suffix-chunk program — match;
        the pages themselves may differ per row).  Batching keeps a hit
        wave as cheap per request as a group prefill: one chunked suffix
        pass and one inject serve the whole wave."""
        bucket = self._bucket_of(len(lead_req.tokens))
        G = self.sched.prefill_group
        take, keep = [], deque()
        for rid, req in self._queue:
            if (len(take) < min(len(free), G) and not self._is_long(req)
                    and self._bucket_of(len(req.tokens)) == bucket):
                keys, h = self.prefix.lookup(req.tokens)
                if h == n_hit:
                    take.append((rid, req, keys))
                    continue
            keep.append((rid, req))
        assert take, "the hit lead must join its own prefix group"
        self._queue = keep
        for (rid, _, _), slot in zip(take, free):
            self._slots.acquire(slot, rid)
        return {"prefix": True, "take": take, "slots": free[:len(take)],
                "bucket": bucket, "n_hit": n_hit}

    def _admit(self) -> None:
        """Plan and launch every admission the queue and free slots
        allow.  Each plan launches as it forms: a group's pin() lands
        its pages in the prefix index (host-side) before the next plan's
        lookup runs, so same-round arrivals sharing a prefix hit on the
        very first wave instead of waiting for the next round."""
        while True:
            g = self._plan_one()
            if g is None:
                return
            if g is not True:
                self._launch(g)

    def _launch(self, g: dict) -> None:
        """Dispatch one prepared admission plan."""
        if g.get("prefix"):
            self._launch_prefix(g)
        else:
            self._launch_group(g)

    def _launch_group(self, g: dict) -> None:
        """Dispatch one prepared group: per-bucket prefill + in-graph
        inject.  Async — the host returns as soon as the work is queued.
        With the prefix cache on, every row's shareable pages are
        registered (sliced from the prefill rows) and pinned for the
        slot's lifetime."""
        logits0, rows, _ = self._prefill(
            self.params, jnp.asarray(g["tokens"]), jnp.asarray(g["lengths"]),
            max_len=self._copy_width(g["bucket"]))
        if self.tel.enabled:
            self.tel.note_compiles("sched.prefill", self._prefill,
                                   shape=f"bucket{g['bucket']}")
            self.tel.counter("sched.admitted", path="group").inc(
                int((g["slots"] < self.sched.max_slots).sum()))
        if self.prefix is not None:
            for i, (keys, slot) in enumerate(zip(g["pkeys"], g["slots"])):
                self.prefix.record(len(keys), 0)
                if keys:
                    self.prefix.pin(int(slot), keys,
                                    rows["k"][:, :, i], rows["v"][:, :, i])
        for rid in g["rids"]:          # after pin: parked pages stay hot
            self._unpark(rid)          # until the new pins hold them
        self._key, sub = jax.random.split(self._key)
        self._pool = self._inject(
            self._pool, jnp.asarray(g["slots"]), rows, logits0,
            jnp.asarray(g["lengths"]), jnp.asarray(g["eos"]),
            jnp.asarray(g["max_new"]), jnp.asarray(g["temps"]), sub)

    def _launch_prefix(self, g: dict) -> None:
        """Admit a wave of prefix-hit requests in one batch: seed each
        row's resident pages into a fresh G-row cache (the copy-on-write
        copies, hoisted to admission — the pool's dense layout makes
        inject the slot's first and only write below the prompt), then
        prefill just the suffixes in page-width chunks attending at the
        full bucket width (the same segment-vs-one-shot bit-identity
        `_advance_staging` relies on).  `prefill_chunk` gathers logits
        per row, so rows whose prompts end in different chunks each keep
        the logits of the chunk holding their final token; a short row's
        later chunks only write pad keys above its prompt, exactly what
        a full-width group prefill leaves there.  One inject lands the
        whole wave through the ordinary page-granular scatter — the same
        compiled program the group path uses.  Every device op is
        dispatched async, so overlap mode pipelines a prefix wave behind
        the in-flight decode chunk like any other."""
        take, H = g["take"], g["n_hit"]
        page = self.sched.page_size
        G = self.sched.prefill_group
        Wc = self._copy_width(g["bucket"])
        seeded = H * page
        kvs = [self.prefix.fetch(keys[:H]) for _, _, keys in take]
        pad = G - len(take)
        kk = jnp.stack([kv["k"] for kv in kvs]
                       + [jnp.zeros_like(kvs[0]["k"])] * pad, axis=2)
        vv = jnp.stack([kv["v"] for kv in kvs]
                       + [jnp.zeros_like(kvs[0]["v"])] * pad, axis=2)
        cache = dict(bb.init_cache(self.cfg, G, Wc))
        cache["k"] = cache["k"].at[:, :, :, :seeded].set(
            kk.astype(cache["k"].dtype))
        cache["v"] = cache["v"].at[:, :, :, :seeded].set(
            vv.astype(cache["v"].dtype))
        toks = np.zeros((G, Wc), np.int32)
        lengths = np.ones((G,), np.int32)        # dummies: 1 valid token
        slots = np.full((G,), self.sched.max_slots, np.int32)
        eos = np.full((G,), -1, np.int32)
        max_new = np.ones((G,), np.int32)
        temps = np.zeros((G,), np.float32)
        for i, ((rid, req, _), slot) in enumerate(zip(take, g["slots"])):
            T = len(req.tokens)
            toks[i, :T] = np.asarray(req.tokens, np.int32)
            lengths[i] = T
            slots[i] = slot
            eos[i] = req.eos_id
            max_new[i] = req.max_new_tokens
            temps[i] = req.temperature
        logits0 = None
        for d in range(seeded, Wc, page):     # seeded <= T-1 on hit rows
            last = np.clip(lengths - 1 - d, 0, page - 1).astype(np.int32)
            lg, cache = self._prefill_chunk(
                self.params, jnp.asarray(toks[:, d:d + page]), cache,
                jnp.int32(d), attend_width=g["bucket"],
                last_index=jnp.asarray(last))
            ends_here = (d <= lengths - 1) & (lengths - 1 < d + page)
            logits0 = lg if logits0 is None else jnp.where(
                jnp.asarray(ends_here)[:, None], lg, logits0)
            if d + page >= int(lengths.max()):
                break
        if self.tel.enabled:
            self.tel.note_compiles("sched.prefill_chunk", self._prefill_chunk,
                                   shape=f"bucket{g['bucket']}")
            self.tel.counter("sched.admitted", path="prefix").inc(len(take))
        for i, ((rid, _, keys), slot) in enumerate(zip(take, g["slots"])):
            self.prefix.record(len(keys), H)
            self.prefix.pin(int(slot), keys, cache["k"][:, :, i],
                            cache["v"][:, :, i])
            self._unpark(rid)          # resumed rows: new pins now hold
        self._key, sub = jax.random.split(self._key)
        self._pool = self._inject(
            self._pool, jnp.asarray(slots), cache, logits0,
            jnp.asarray(lengths), jnp.asarray(eos),
            jnp.asarray(max_new), jnp.asarray(temps), sub)

    # ------------------------------------------------- chunked prefill --

    def _start_staging(self, rid: int, req, slot: int) -> None:
        """Claim a slot for a long admission; its prompt prefills one
        `prefill_segment`-token slice per scheduling round.  Resident
        prefix pages seed the staged cache in whole segments (staging
        advances a segment at a time, so a partial segment can't be
        skipped) and `depth` starts past them — a long re-admission of a
        shared header pays only its tail's segments."""
        seg = self.sched.prefill_segment
        page = self.sched.page_size
        bucket = self._bucket_of(len(req.tokens))
        T = len(req.tokens)
        n_segs = round_up(bucket, seg) // seg
        toks = np.zeros((n_segs * seg,), np.int32)
        toks[:T] = np.asarray(req.tokens, np.int32)
        cache = bb.init_cache(self.cfg, 1, n_segs * seg)
        depth, keys = 0, []
        if self.prefix is not None:
            keys, n_hit = self.prefix.lookup(req.tokens)
            depth = (n_hit * page // seg) * seg    # whole segments only
            self.prefix.record(len(keys), depth // page)
            if depth:
                kv = self.prefix.fetch(keys[:-(-depth // page)])
                cache = dict(cache)
                cache["k"] = cache["k"].at[:, :, :, :depth].set(
                    kv["k"][:, :, None, :depth].astype(cache["k"].dtype))
                cache["v"] = cache["v"].at[:, :, :, :depth].set(
                    kv["v"][:, :, None, :depth].astype(cache["v"].dtype))
        self._slots.acquire(slot, rid)
        self._staging.append({
            "rid": rid, "req": req, "slot": slot, "depth": depth, "T": T,
            "bucket": bucket, "tokens": toks, "logits0": None, "keys": keys,
            # staging cache width: whole segments covering the bucket, so
            # every segment's K/V write lands without clamping
            "cache": cache,
        })

    def _advance_staging(self) -> None:
        """Run one prefill segment for the staged admission (if any).
        Attention spans the bucket width at every segment, which keeps
        the staged rows bit-identical to a one-shot bucketed prefill;
        segments stop once the prompt tail has landed."""
        if not self._staging:
            return
        st = self._staging[0]
        seg = self.sched.prefill_segment
        d = st["depth"]
        toks = jnp.asarray(st["tokens"][None, d:d + seg])
        last = min(max(st["T"] - 1 - d, 0), seg - 1)
        logits, st["cache"] = self._prefill_chunk(
            self.params, toks, st["cache"], jnp.int32(d),
            attend_width=st["bucket"], last_index=jnp.int32(last))
        if self.tel.enabled:
            self.tel.note_compiles("sched.prefill_chunk", self._prefill_chunk,
                                   shape=f"bucket{st['bucket']}")
        if d <= st["T"] - 1 < d + seg:
            st["logits0"] = logits          # segment holding the last token
        st["depth"] = d + seg
        if st["depth"] >= st["T"]:
            self._staging.remove(st)
            self._finish_staging(st)

    def _finish_staging(self, st: dict) -> None:
        """The staged cache joins the pool through the same page-granular
        inject as one-shot admissions (first token sampled in-graph)."""
        req = st["req"]
        if self.tel.enabled:
            self.tel.counter("sched.admitted", path="staged").inc()
        if self.prefix is not None and st["keys"]:
            self.prefix.pin(st["slot"], st["keys"],
                            st["cache"]["k"][:, :, 0], st["cache"]["v"][:, :, 0])
        self._unpark(st["rid"])        # resumed rows: new pins now hold
        self._key, sub = jax.random.split(self._key)
        self._pool = self._inject(
            self._pool, jnp.asarray([st["slot"]]), st["cache"],
            st["logits0"], jnp.asarray([st["T"]], jnp.int32),
            jnp.asarray([req.eos_id], jnp.int32),
            jnp.asarray([req.max_new_tokens], jnp.int32),
            jnp.asarray([req.temperature], jnp.float32), sub)

    # ----------------------------------------------------------- loop --

    def _active_mask(self) -> np.ndarray:
        stag = self._staging_slots()
        return np.asarray([r is not None and i not in stag
                           for i, r in enumerate(self._slot_rid)])

    def _complete(self, fin: list[int], buf, gen, *,
                  timed_out: bool = False) -> list[int]:
        """Release finished slots and record their Completions; freed
        slots drop to depth 0 so the paged decode kernel's max-depth
        branch follows live occupancy."""
        from repro.serve.engine import Completion
        if self.tel.enabled and fin:
            self.tel.counter(
                "sched.evicted",
                reason="deadline" if timed_out else "finished").inc(len(fin))
        out = []
        for i in fin:
            rid = self._slots.release(i)
            if self.prefix is not None:
                self.prefix.release(i)     # unpin the slot's prefix pages
            self._deadlines.pop(rid, None)
            self._req_of.pop(rid, None)
            self._unpark(rid)
            toks = buf[i, :gen[i]].astype(np.int32)
            prefix = self._resume.pop(rid, None)
            if prefix is not None:         # resumed rows report the full
                toks = np.concatenate([prefix, toks])      # token stream
            self._results[rid] = Completion(toks, len(toks),
                                            timed_out=timed_out)
            out.append(rid)
        self._pool["cache_len"] = (
            self._pool["cache_len"].at[jnp.asarray(fin)].set(0))
        return out

    # ------------------------------------------------------ deadlines --

    def _expire_deadlines(self) -> list[int]:
        """Deadline-evict, between chunks, every request whose deadline
        has lapsed: queued requests resolve empty, a staging admission
        aborts its prefill and frees its slot, pooled slots evict with
        the tokens generated so far.  A request past its deadline never
        occupies device work again — under a stalled pool this is the
        exit that keeps `run()` from hanging."""
        if not self._deadlines:
            return []
        from repro.serve.engine import Completion
        now = self._clock()
        expired = {rid for rid, at in self._deadlines.items() if at <= now}
        if not expired:
            return []
        out = []
        # queued, never admitted: nothing was generated in time
        keep = deque()
        for rid, req in self._queue:
            if rid in expired:
                # a resumed request expiring in queue still resolves with
                # the tokens it generated before suspension — preemption
                # never silently drops work
                pre = self._resume.pop(rid, None)
                toks = pre if pre is not None else np.zeros((0,), np.int32)
                self._results[rid] = Completion(toks, len(toks),
                                                timed_out=True)
                self._deadlines.pop(rid)
                self._req_of.pop(rid, None)
                self._unpark(rid)
                out.append(rid)
            else:
                keep.append((rid, req))
        self._queue = keep
        # staging: abort the chunked prefill, free its claimed slot
        for st in [s for s in self._staging if s["rid"] in expired]:
            self._staging.remove(st)
            self._slots.release(st["slot"], st["rid"])
            self._deadlines.pop(st["rid"])
            self._req_of.pop(st["rid"], None)
            self._unpark(st["rid"])
            pre = self._resume.pop(st["rid"], None)
            toks = pre if pre is not None else np.zeros((0,), np.int32)
            self._results[st["rid"]] = Completion(toks, len(toks),
                                                  timed_out=True)
            out.append(st["rid"])
        # pooled: evict with partial tokens (host copy like _drain's)
        fin = [i for i, rid in enumerate(self._slot_rid)
               if rid in expired]
        if fin:
            out.extend(self._complete(
                fin, np.asarray(self._pool["buf"]),
                np.asarray(self._pool["gen"]), timed_out=True))
        return out

    def _drain(self) -> list[int]:
        """Evict finished slots: one host copy of buf/gen per segment."""
        done = np.asarray(self._pool["done"])
        stag = self._staging_slots()
        fin = [i for i, rid in enumerate(self._slot_rid)
               if rid is not None and done[i] and i not in stag]
        if self.stream_cb is not None:
            live = [i for i, rid in enumerate(self._slot_rid)
                    if rid is not None and i not in stag and not done[i]]
            self._stream_rows(live, self._pool["buf"], self._pool["gen"],
                              self._slot_rid)
        if not fin:
            return []
        return self._complete(fin, np.asarray(self._pool["buf"]),
                              np.asarray(self._pool["gen"]))

    def _stream_rows(self, rows: list[int], buf, gen, rids) -> None:
        """Publish tokens-so-far for still-running slots (the finishers'
        full buffers travel in their Completions instead).  One buf/gen
        host copy per streamed round — the price of streaming, paid only
        when a `stream_cb` is attached."""
        if not rows:
            return
        buf, gen = np.asarray(buf), np.asarray(gen)
        for i in rows:
            toks = buf[i, :gen[i]]
            pre = self._resume.get(rids[i])
            if pre is not None:            # resumed rows stream the full
                toks = np.concatenate([pre, toks])         # token stream
            self.stream_cb(rids[i], toks)

    def _snapshot_chunk(self, rids: list, active: np.ndarray) -> None:
        """Capture the just-dispatched chunk's observable state and start
        its device->host copies; the host blocks on them only next round,
        after the following round's work has been dispatched."""
        pend = {"done": self._pool["done"], "buf": self._pool["buf"],
                "gen": self._pool["gen"], "rids": rids, "active": active}
        try:
            # the round's one blocking read; buf/gen stay device-side and
            # are only pulled on rounds that actually evict
            pend["done"].copy_to_host_async()
        except AttributeError:          # non-Array leaves under tracing
            pass
        self._pending = pend

    def _drain_pending(self) -> list[int]:
        """Evict the finishers of the *previous* round's chunk.  Only
        slots that were active in that chunk AND still hold the same
        occupant are eligible: a slot freed and re-admitted in between
        carries a fresher request whose done flag this snapshot cannot
        know, and a then-staging slot's done flag is the previous
        occupant's leftover."""
        p, self._pending = self._pending, None
        if p is None:
            return []
        done = np.asarray(p["done"])
        eligible = [i for i, rid in enumerate(self._slot_rid)
                    if rid is not None and p["active"][i]
                    and p["rids"][i] == rid]
        fin = [i for i in eligible if done[i]]
        if self.stream_cb is not None:
            # stream from the drained chunk's own snapshot: the rows are
            # consistent with the done flags just read, even though the
            # next chunk is already in flight on the device
            self._stream_rows([i for i in eligible if not done[i]],
                              p["buf"], p["gen"], p["rids"])
        if not fin:
            return []
        return self._complete(fin, np.asarray(p["buf"]),
                              np.asarray(p["gen"]))

    def _dispatch_chunk(self) -> Optional[np.ndarray]:
        """Dispatch one decode chunk over the occupied non-staging slots;
        returns the active mask used (None when nothing is decodable, or
        when a fault has this round's executor stalled — deadlines keep
        aging either way)."""
        if self.faults is not None and \
                self.faults.chunk_stalled(self._round - 1):
            return None
        active = self._active_mask()
        if not active.any():
            return None
        self._key, sub = jax.random.split(self._key)
        self._pool, _ = self._chunk(self.params, self._pool,
                                    jnp.asarray(active), sub,
                                    jnp.int32(self.sched.chunk))
        return active

    def step(self) -> list[int]:
        """One scheduling round.  Serialized mode: advance the staged
        prefill a segment, admit groups while slots are free, decode one
        chunk, block on the drain.  Overlap mode pipelines the same round
        against the device (see `_step_overlapped`).  Returns completed
        request ids (overlap mode reports a completion one round after
        its chunk, once its async done-copy has landed).  Expired
        deadlines evict first, so a deadline-carrying request never costs
        another prefill segment or decode chunk past its budget."""
        self._round += 1                # 0-based round index while inside:
                                        # _dispatch_chunk sees _round - 1
        if self.faults is not None and self.faults.crashed(self._round - 1):
            # scripted engine death: the pool and every in-flight chunk
            # are lost mid-round.  serve.recovery replays the journal
            # into a fresh stack and regenerates the lost tokens
            # bit-identically
            from repro.serve.faults import EngineCrashError
            raise EngineCrashError(
                f"scripted engine crash at round {self._round - 1}")
        with self._span("round"):
            expired = self._expire_deadlines()
            if self.sched.overlap:
                out = expired + self._step_overlapped()
            else:
                with self._span("prefill_segment"):
                    self._advance_staging()
                with self._span("admit"):
                    self._admit()
                with self._span("decode_chunk"):
                    dispatched = self._dispatch_chunk()
                if dispatched is None:
                    out = expired
                else:
                    with self._span("evict"):
                        out = expired + self._drain()
        self.export_metrics()
        return out

    def _step_overlapped(self) -> list[int]:
        """One pipelined round: round k's prefill work is dispatched, and
        its admissions bucketed/tokenized, while round k-1's chunk is
        still in flight — the staged segment, the injects and the decode
        chunk queue back-to-back on the device with no host sync between
        them, and the host's one blocking read (round k-1's done flags,
        whose device->host copy started at dispatch) sits behind a full
        round of queued work instead of stalling an idle device.  Evict/
        admit timing is round-identical to serialized mode: chunk k-1's
        finishers free their slots before chunk k dispatches, a second
        admission pass fills them, and completions simply report one
        round late."""
        with self._span("prefill_segment"):
            self._advance_staging()            # prefill segment (async)
        with self._span("admit"):
            self._admit()                      # overlap chunk k-1: bucket/
                                               # tokenize + inject dispatch
        with self._span("evict"):
            out = self._drain_pending()        # round k-1 lands (no idle
        with self._span("admit"):
            self._admit()                      # wait); freed slots admit
                                               # before this round's chunk
        rids = list(self._slot_rid)            # occupancy at dispatch time
        with self._span("decode_chunk"):
            active = self._dispatch_chunk()
        if active is not None:
            self._snapshot_chunk(rids, active)
        return out

    def run(self) -> dict:
        """Drain queue and pool; returns (and forgets) {rid: Completion}."""
        while self._queue or self._staging or self._slots.any_occupied():
            self.step()
        out, self._results = self._results, {}
        return out
