"""Backbone assembly: ArchConfig -> init / forward / prefill / decode.

Layers are grouped into homogeneous *superblocks* (dense: 1 layer; jamba:
1 attention + 7 mamba; xLSTM: 7 mLSTM + 1 sLSTM) whose parameters are
stacked with a leading (n_superblocks,) axis and executed with
jax.lax.scan — this keeps HLO size and compile time bounded at
72-layer / 512-device scale.  Each superblock body is jax.checkpoint'ed
(remat) so train-time activation memory is O(layers * B * T * d_model)
instead of O(layers * B * T * d_ff).

Modes:
  forward(..., labels)      training loss (+ MoE aux losses)
  prefill(...)              logits of last position + decode cache
  decode_step(...)          one token with ring-buffer KV / recurrent state
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.nn.activations import mlp_apply, mlp_init, swiglu_ffn_apply, swiglu_ffn_init
from repro.nn.attention import (
    attention_apply,
    attention_decode_apply,
    attention_init,
    cross_attention_apply,
    cross_attention_decode,
    cross_kv,
    flash_attention,
    project_qkv,
)
from repro.nn.linear import dense_apply, dense_init, embedding_apply, embedding_init
from repro.nn.moe import moe_apply, moe_init
from repro.nn.module import split_keys
from repro.nn.norm import layernorm_apply, layernorm_init, rmsnorm_apply, rmsnorm_init
from repro.nn.rope import apply_rope
from repro.nn.ssm import (
    mamba_apply,
    mamba_decode_apply,
    mamba_decode_init_state,
    mamba_init,
)
from repro.nn.xlstm import (
    mlstm_apply,
    mlstm_decode_apply,
    mlstm_decode_init_state,
    mlstm_init,
    slstm_apply,
    slstm_decode_apply,
    slstm_decode_init_state,
    slstm_init,
)

# --------------------------------------------------------------- helpers ---


def _norm_init(cfg: ArchConfig, dim=None):
    dim = dim or cfg.d_model
    return rmsnorm_init(dim, cfg.dtype) if cfg.norm == "rmsnorm" else layernorm_init(dim, cfg.dtype)


def _norm_apply(cfg: ArchConfig, p, x):
    return rmsnorm_apply(p, x) if cfg.norm == "rmsnorm" else layernorm_apply(p, x)


def sinusoidal_positions(T: int, d: int, offset=0) -> jnp.ndarray:
    pos = (jnp.arange(T) + offset)[:, None].astype(jnp.float32)
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-math.log(10000.0) / d))
    pe = jnp.zeros((T, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def sublayer_specs(cfg: ArchConfig) -> list[dict]:
    """Per-sublayer spec for one superblock."""
    specs = []
    for j in range(cfg.superblock):
        if cfg.xlstm is not None:
            kind = "slstm" if j == cfg.xlstm.slstm_index else "mlstm"
            ffn = "none"
        elif cfg.hybrid is not None:
            kind = "attn" if j == cfg.hybrid.attn_index else "mamba"
            ffn = "moe" if (cfg.moe and j % cfg.moe.every == cfg.moe.every - 1) else "dense"
        else:
            kind = "attn"
            ffn = "moe" if cfg.moe else "dense"
        specs.append({"kind": kind, "ffn": ffn})
    return specs


def _mamba_kwargs(cfg: ArchConfig) -> dict:
    h = cfg.hybrid
    return dict(d_state=h.d_state, d_conv=h.d_conv)


# ------------------------------------------------------------------ init ---


def _init_sublayer(cfg: ArchConfig, spec: dict, key) -> dict:
    kk = split_keys(key, ["mix", "norm", "ffn", "ffn_norm", "extra", "shared"])
    p: dict[str, Any] = {"norm": _norm_init(cfg)}
    if spec["kind"] == "attn":
        p["attn"] = attention_init(kk["mix"], cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.resolved_head_dim,
                                   qkv_bias=cfg.qkv_bias, dtype=cfg.dtype)
    elif spec["kind"] == "mamba":
        p["mamba"] = mamba_init(kk["mix"], cfg.d_model, expand=cfg.hybrid.expand,
                                d_state=cfg.hybrid.d_state, d_conv=cfg.hybrid.d_conv,
                                dtype=cfg.dtype)
    elif spec["kind"] == "mlstm":
        p["cell"] = mlstm_init(kk["mix"], cfg.d_model, cfg.n_heads, dtype=cfg.dtype)
    elif spec["kind"] == "slstm":
        p["cell"] = slstm_init(kk["mix"], cfg.d_model, cfg.n_heads, dtype=cfg.dtype)

    if spec["ffn"] == "dense":
        p["ffn_norm"] = _norm_init(cfg)
        if cfg.norm == "layernorm":  # whisper-style plain MLP
            p["ffn"] = mlp_init(kk["ffn"], cfg.d_model, cfg.d_ff, dtype=cfg.dtype)
        else:
            p["ffn"] = swiglu_ffn_init(kk["ffn"], cfg.d_model, cfg.d_ff, dtype=cfg.dtype)
    elif spec["ffn"] == "moe":
        p["ffn_norm"] = _norm_init(cfg)
        p["moe"] = moe_init(kk["ffn"], cfg.d_model, cfg.moe.expert_d_ff,
                            cfg.moe.n_experts, dtype=cfg.dtype)
        if cfg.moe.dense_residual_ff:
            p["dense_res"] = swiglu_ffn_init(kk["extra"], cfg.d_model,
                                             cfg.moe.dense_residual_ff, dtype=cfg.dtype)
        if cfg.moe.shared_expert_ff:
            p["shared"] = swiglu_ffn_init(kk["shared"], cfg.d_model,
                                          cfg.moe.shared_expert_ff, dtype=cfg.dtype)
    return p


def _init_superblock(cfg: ArchConfig, key):
    specs = sublayer_specs(cfg)
    keys = jax.random.split(key, len(specs))
    return tuple(_init_sublayer(cfg, s, k) for s, k in zip(specs, keys))


def _init_encoder_layer(cfg: ArchConfig, key) -> dict:
    kk = split_keys(key, ["attn", "norm", "ffn", "ffn_norm"])
    return {
        "norm": _norm_init(cfg),
        "attn": attention_init(kk["attn"], cfg.d_model, cfg.n_heads, cfg.n_heads,
                               cfg.resolved_head_dim, dtype=cfg.dtype),
        "ffn_norm": _norm_init(cfg),
        "ffn": mlp_init(kk["ffn"], cfg.d_model, cfg.d_ff, dtype=cfg.dtype),
    }


def _init_decoder_layer_encdec(cfg: ArchConfig, key) -> dict:
    kk = split_keys(key, ["self", "cross", "norm", "cross_norm", "ffn", "ffn_norm"])
    return {
        "norm": _norm_init(cfg),
        "attn": attention_init(kk["self"], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.resolved_head_dim, dtype=cfg.dtype),
        "cross_norm": _norm_init(cfg),
        "cross": attention_init(kk["cross"], cfg.d_model, cfg.n_heads, cfg.n_heads,
                                cfg.resolved_head_dim, dtype=cfg.dtype),
        "ffn_norm": _norm_init(cfg),
        "ffn": mlp_init(kk["ffn"], cfg.d_model, cfg.d_ff, dtype=cfg.dtype),
    }


def init_params(cfg: ArchConfig, key) -> dict:
    kk = split_keys(key, ["embed", "blocks", "final_norm", "head", "vision",
                          "encoder"])
    params: dict[str, Any] = {
        "embed": embedding_init(kk["embed"], cfg.vocab, cfg.d_model, cfg.dtype),
        "final_norm": _norm_init(cfg),
    }
    if cfg.encdec is not None:
        enc_keys = jax.random.split(kk["encoder"], cfg.encdec.n_encoder_layers)
        params["encoder"] = jax.vmap(partial(_init_encoder_layer, cfg))(enc_keys)
        params["enc_final_norm"] = _norm_init(cfg)
        dec_keys = jax.random.split(kk["blocks"], cfg.n_layers)
        params["blocks"] = jax.vmap(partial(_init_decoder_layer_encdec, cfg))(dec_keys)
    else:
        sb_keys = jax.random.split(kk["blocks"], cfg.n_superblocks)
        params["blocks"] = jax.vmap(partial(_init_superblock, cfg))(sb_keys)
    if cfg.vlm is not None:
        params["vision_proj"] = dense_init(kk["vision"], cfg.vlm.vision_dim,
                                           cfg.d_model, dtype=cfg.dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(kk["head"], cfg.d_model, cfg.vocab,
                                       use_bias=False, dtype=cfg.dtype)
    return params


# --------------------------------------------------------------- forward ---


def _apply_ffn(cfg: ArchConfig, spec, p, x, *, dropless: bool = False):
    """Post-mixer FFN sublayer.  Returns (x, aux).

    dropless=True (inference) sizes MoE capacity so no token is dropped;
    training keeps the configured capacity factor (tokens over capacity
    fall through the residual, standard GShard/Switch behaviour).
    """
    aux = {}
    if spec["ffn"] == "none":
        return x, aux
    h = _norm_apply(cfg, p["ffn_norm"], x)
    if spec["ffn"] == "dense":
        if cfg.norm == "layernorm":
            y = mlp_apply(p["ffn"], h)
        else:
            y = swiglu_ffn_apply(p["ffn"], h)
    else:
        if dropless:
            # provably dropless when the expert count is small; for very
            # wide MoEs (arctic: 128e) a 4x capacity factor keeps memory
            # bounded with negligible overflow probability
            e_over_k = cfg.moe.n_experts / cfg.moe.top_k
            cap = e_over_k if cfg.moe.n_experts <= 8 else min(4.0, e_over_k)
        else:
            cap = cfg.moe.capacity_factor
        y, aux = moe_apply(p["moe"], h, top_k=cfg.moe.top_k,
                           capacity_factor=cap)
        if "dense_res" in p:
            y = y + swiglu_ffn_apply(p["dense_res"], h)
        if "shared" in p:
            y = y + swiglu_ffn_apply(p["shared"], h)
    return x + y, aux


def _apply_sublayer(cfg: ArchConfig, spec, p, x, *, window: int,
                    dropless: bool = False, kv_valid_len=None):
    """Full-sequence (train/prefill) sublayer.  Returns (x, kv_or_state, aux)."""
    h = _norm_apply(cfg, p["norm"], x)
    state = None
    if spec["kind"] == "attn":
        y, k, v = attention_apply(
            p["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, causal=True, window=window,
            rope_theta=cfg.rope_theta, return_kv=True,
            kv_valid_len=kv_valid_len)
        state = (k, v)
    elif spec["kind"] == "mamba":
        y, state = mamba_apply(p["mamba"], h, return_state=True, **_mamba_kwargs(cfg))
    elif spec["kind"] == "mlstm":
        y, state = mlstm_apply(p["cell"], h, n_heads=cfg.n_heads, return_state=True)
    elif spec["kind"] == "slstm":
        y, state = slstm_apply(p["cell"], h, n_heads=cfg.n_heads, return_state=True)
    x = x + y
    x, aux = _apply_ffn(cfg, spec, p, x, dropless=dropless)
    return x, state, aux


def _zero_aux():
    return {"load_balance_loss": jnp.zeros((), jnp.float32),
            "dropped_fraction": jnp.zeros((), jnp.float32)}


def _acc_aux(acc, aux, n: int):
    if not aux:
        return acc
    return {"load_balance_loss": acc["load_balance_loss"] + aux["load_balance_loss"] / n,
            "dropped_fraction": acc["dropped_fraction"] + aux["dropped_fraction"] / n}


def _moe_layer_count(cfg: ArchConfig) -> int:
    return sum(1 for s in sublayer_specs(cfg) if s["ffn"] == "moe") * cfg.n_superblocks or 1


def _run_superblocks(cfg: ArchConfig, params, x, *, window: int,
                     collect_cache: bool = False, remat: bool = True,
                     dropless: bool = False, kv_valid_len=None):
    """Scan over stacked superblocks.  Returns (x, aux, caches or None)."""
    specs = sublayer_specs(cfg)
    n_moe = _moe_layer_count(cfg)

    # NOTE: sb_params is a tuple of per-sublayer dicts (the scan strips the
    # stacked leading axis); iterate positionally.
    def body(carry, sb_params):
        h, aux_acc = carry
        states = []
        for spec, p in zip(specs, sb_params):
            h, st, aux = _apply_sublayer(cfg, spec, p, h, window=window,
                                         dropless=dropless,
                                         kv_valid_len=kv_valid_len)
            aux_acc = _acc_aux(aux_acc, aux, n_moe)
            states.append(st)
        out = _stack_states(cfg, specs, states) if collect_cache else None
        return (h, aux_acc), out

    if remat:
        body = jax.checkpoint(body)
    (x, aux), caches = jax.lax.scan(body, (x, _zero_aux()), params["blocks"])
    return x, aux, caches


def _stack_states(cfg, specs, states):
    """Group per-sublayer prefill states by kind for the decode cache."""
    out = {}
    attn_states = [s for spec, s in zip(specs, states) if spec["kind"] == "attn"]
    if attn_states:
        out["k"] = jnp.stack([k for k, _ in attn_states])   # (n_attn, B, T, Hkv, D)
        out["v"] = jnp.stack([v for _, v in attn_states])
    mamba_states = [s for spec, s in zip(specs, states) if spec["kind"] == "mamba"]
    if mamba_states:
        out["mamba_conv"] = jnp.stack([s["conv"] for s in mamba_states])
        out["mamba_ssm"] = jnp.stack([s["ssm"] for s in mamba_states])
    ml = [s for spec, s in zip(specs, states) if spec["kind"] == "mlstm"]
    if ml:
        out["mlstm_C"] = jnp.stack([s["C"] for s in ml])
        out["mlstm_n"] = jnp.stack([s["n"] for s in ml])
        out["mlstm_m"] = jnp.stack([s["m"] for s in ml])
    sl = [s for spec, s in zip(specs, states) if spec["kind"] == "slstm"]
    if sl:
        out["slstm_h"] = jnp.stack([s["h"] for s in sl])
        out["slstm_c"] = jnp.stack([s["c"] for s in sl])
        out["slstm_n"] = jnp.stack([s["n"] for s in sl])
        out["slstm_m"] = jnp.stack([s["m"] for s in sl])
    return out


def _readout_weight(cfg: ArchConfig, params):
    if cfg.tie_embeddings:
        return params["embed"]["table"].T          # (d, V)
    return params["lm_head"]["w"]


def chunked_cross_entropy(x, w_vocab, labels, *, chunk: int = 512,
                          ignore_label: int = -100):
    """Mean CE without materializing (B, T, V): scan over T chunks.

    x: (B, T, d); w_vocab: (d, V); labels: (B, T) int32.
    """
    B, T, d = x.shape
    Tc = min(chunk, T)
    n_chunks = -(-T // Tc)
    Tp = n_chunks * Tc
    xp = jnp.pad(x, ((0, 0), (0, Tp - T), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, Tp - T)), constant_values=ignore_label)

    V = w_vocab.shape[-1]

    def body(acc, idx):
        xc = jax.lax.dynamic_slice_in_dim(xp, idx * Tc, Tc, axis=1)
        lc = jax.lax.dynamic_slice_in_dim(lp, idx * Tc, Tc, axis=1)
        logits = (xc.astype(jnp.float32) @ w_vocab.astype(jnp.float32))
        logz = jax.nn.logsumexp(logits, axis=-1)
        # gold logit via a fused one-hot reduction: keeps the vocab axis
        # sharded (a take_along_axis would force an all-gather of logits)
        onehot = (jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
                  == jnp.maximum(lc, 0)[..., None])
        gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
        mask = (lc != ignore_label).astype(jnp.float32)
        loss_sum, cnt = acc
        return (loss_sum + jnp.sum((logz - gold) * mask), cnt + jnp.sum(mask)), None

    (loss_sum, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                      jnp.arange(n_chunks))
    return loss_sum / jnp.maximum(cnt, 1.0)


# ------------------------------------------------------------- embedding ---


def _embed_inputs(cfg: ArchConfig, params, batch) -> tuple[jnp.ndarray, jnp.ndarray | None]:
    """Returns (x (B, T, d), labels or None)."""
    tokens = batch["tokens"]
    x = embedding_apply(params["embed"], tokens)
    labels = batch.get("labels")
    if cfg.vlm is not None and "patches" in batch:
        pv = dense_apply(params["vision_proj"], batch["patches"].astype(cfg.dtype))
        x = jnp.concatenate([pv, x], axis=1)
        if labels is not None:
            pad = jnp.full(pv.shape[:2], -100, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
    if cfg.rope_theta == 0:  # sinusoidal positions (whisper)
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)
    return x, labels


def _run_encoder(cfg: ArchConfig, params, frames) -> jnp.ndarray:
    """Whisper-style encoder over stubbed frame embeddings (B, F, d)."""
    x = frames.astype(cfg.dtype)
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)

    def body(h, layer):
        a = _norm_apply(cfg, layer["norm"], h)
        h = h + attention_apply(layer["attn"], a, n_heads=cfg.n_heads,
                                n_kv_heads=cfg.n_heads,
                                head_dim=cfg.resolved_head_dim, causal=False,
                                rope_theta=0.0)
        f = _norm_apply(cfg, layer["ffn_norm"], h)
        h = h + mlp_apply(layer["ffn"], f)
        return h, None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["encoder"])
    return _norm_apply(cfg, params["enc_final_norm"], x)


def _run_decoder_encdec(cfg: ArchConfig, params, x, enc_out, *,
                        collect_cache: bool = False):
    """Whisper-style decoder (full sequence)."""

    def body(carry, layer):
        h = carry
        a = _norm_apply(cfg, layer["norm"], h)
        sa, k, v = attention_apply(layer["attn"], a, n_heads=cfg.n_heads,
                                   n_kv_heads=cfg.n_kv_heads,
                                   head_dim=cfg.resolved_head_dim, causal=True,
                                   rope_theta=0.0, return_kv=True)
        h = h + sa
        c = _norm_apply(cfg, layer["cross_norm"], h)
        ck, cv = cross_kv(layer["cross"], enc_out, n_kv_heads=cfg.n_heads,
                          head_dim=cfg.resolved_head_dim)
        h = h + cross_attention_apply(layer["cross"], c, ck, cv,
                                      n_heads=cfg.n_heads,
                                      head_dim=cfg.resolved_head_dim)
        f = _norm_apply(cfg, layer["ffn_norm"], h)
        h = h + mlp_apply(layer["ffn"], f)
        cache = {"k": k, "v": v, "ck": ck, "cv": cv} if collect_cache else None
        return h, cache

    x, caches = jax.lax.scan(jax.checkpoint(body), x, params["blocks"])
    return x, caches


# ------------------------------------------------------------ public API ---


def forward_loss(cfg: ArchConfig, params, batch, *, window: int = 0,
                 loss_chunk: int = 512):
    """Training forward: mean next-token CE + aux losses.

    batch: tokens (B,T), labels (B,T) [+ patches/frames for vlm/audio].
    """
    window = window or cfg.sliding_window
    if cfg.encdec is not None:
        enc_out = _run_encoder(cfg, params, batch["frames"])
        x, labels = _embed_inputs(cfg, params, batch)
        x, _ = _run_decoder_encdec(cfg, params, x, enc_out)
        aux = _zero_aux()
    else:
        x, labels = _embed_inputs(cfg, params, batch)
        x, aux, _ = _run_superblocks(cfg, params, x, window=window)
    x = _norm_apply(cfg, params["final_norm"], x)
    ce = chunked_cross_entropy(x, _readout_weight(cfg, params), labels,
                               chunk=loss_chunk)
    lb_weight = 0.01 if cfg.moe is not None else 0.0
    loss = ce + lb_weight * aux["load_balance_loss"]
    metrics = {"ce": ce, **aux}
    return loss, metrics


def forward_hidden(cfg: ArchConfig, params, batch, *, window: int = 0):
    """Final hidden states (B, T, d) — used by AgileNN's remote path."""
    window = window or cfg.sliding_window
    if cfg.encdec is not None:
        enc_out = _run_encoder(cfg, params, batch["frames"])
        x, _ = _embed_inputs(cfg, params, batch)
        x, _ = _run_decoder_encdec(cfg, params, x, enc_out)
    else:
        x, _ = _embed_inputs(cfg, params, batch)
        x, _, _ = _run_superblocks(cfg, params, x, window=window)
    return _norm_apply(cfg, params["final_norm"], x)


# ------------------------------------------------------------- decoding ----


def cache_window(cfg: ArchConfig, context_len: int, *, long_context: bool = False) -> int:
    """KV ring-buffer capacity for a decode context of `context_len`."""
    if cfg.sliding_window:
        return min(cfg.sliding_window, context_len)
    if long_context and cfg.hybrid is None and cfg.xlstm is None:
        # full-attention archs switch to the sliding-window variant at 500k
        return min(cfg.long_context_window, context_len)
    return context_len


def init_cache(cfg: ArchConfig, batch: int, context_len: int, *,
               long_context: bool = False) -> dict:
    """Zero decode cache (the dry-run passes ShapeDtypeStructs of this tree)."""
    specs = sublayer_specs(cfg)
    n_sb = cfg.n_superblocks
    S = cache_window(cfg, context_len, long_context=long_context)
    hd = cfg.resolved_head_dim
    out: dict[str, Any] = {}
    if cfg.encdec is not None:
        F = cfg.encdec.n_frames
        L = cfg.n_layers
        out["k"] = jnp.zeros((L, batch, S, cfg.n_kv_heads, hd), cfg.dtype)
        out["v"] = jnp.zeros((L, batch, S, cfg.n_kv_heads, hd), cfg.dtype)
        out["ck"] = jnp.zeros((L, batch, F, cfg.n_heads, hd), cfg.dtype)
        out["cv"] = jnp.zeros((L, batch, F, cfg.n_heads, hd), cfg.dtype)
        return out
    n_attn = sum(1 for s in specs if s["kind"] == "attn")
    n_mamba = sum(1 for s in specs if s["kind"] == "mamba")
    n_ml = sum(1 for s in specs if s["kind"] == "mlstm")
    n_sl = sum(1 for s in specs if s["kind"] == "slstm")
    if n_attn:
        shape = (n_sb, n_attn, batch, S, cfg.n_kv_heads, hd)
        out["k"] = jnp.zeros(shape, cfg.dtype)
        out["v"] = jnp.zeros(shape, cfg.dtype)
    if n_mamba:
        h = cfg.hybrid
        d_inner = h.expand * cfg.d_model
        out["mamba_conv"] = jnp.zeros((n_sb, n_mamba, batch, h.d_conv - 1, d_inner), cfg.dtype)
        out["mamba_ssm"] = jnp.zeros((n_sb, n_mamba, batch, d_inner, h.d_state), jnp.float32)
    if n_ml:
        out["mlstm_C"] = jnp.zeros((n_sb, n_ml, batch, cfg.n_heads, hd, hd), jnp.float32)
        out["mlstm_n"] = jnp.zeros((n_sb, n_ml, batch, cfg.n_heads, hd), jnp.float32)
        out["mlstm_m"] = jnp.full((n_sb, n_ml, batch, cfg.n_heads), -1e30, jnp.float32)
    if n_sl:
        out["slstm_h"] = jnp.zeros((n_sb, n_sl, batch, cfg.d_model), cfg.dtype)
        out["slstm_c"] = jnp.zeros((n_sb, n_sl, batch, cfg.d_model), jnp.float32)
        out["slstm_n"] = jnp.zeros((n_sb, n_sl, batch, cfg.d_model), jnp.float32)
        out["slstm_m"] = jnp.full((n_sb, n_sl, batch, cfg.d_model), -1e30, jnp.float32)
    return out


def _decode_sublayer(cfg: ArchConfig, spec, p, x, cache_sb, counters, cache_len):
    """One-token sublayer.  counters track per-kind index within superblock."""
    h = _norm_apply(cfg, p["norm"], x)
    if spec["kind"] == "attn":
        i = counters["attn"]
        y, k_new, v_new = attention_decode_apply(
            p["attn"], h, cache_sb["k"][i], cache_sb["v"][i], cache_len,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta)
        cache_sb = dict(cache_sb)
        cache_sb["k"] = cache_sb["k"].at[i].set(k_new)
        cache_sb["v"] = cache_sb["v"].at[i].set(v_new)
        counters["attn"] += 1
    elif spec["kind"] == "mamba":
        i = counters["mamba"]
        st = {"conv": cache_sb["mamba_conv"][i], "ssm": cache_sb["mamba_ssm"][i]}
        y, st = mamba_decode_apply(p["mamba"], h, st, **_mamba_kwargs(cfg))
        cache_sb = dict(cache_sb)
        cache_sb["mamba_conv"] = cache_sb["mamba_conv"].at[i].set(st["conv"].astype(cache_sb["mamba_conv"].dtype))
        cache_sb["mamba_ssm"] = cache_sb["mamba_ssm"].at[i].set(st["ssm"])
        counters["mamba"] += 1
    elif spec["kind"] == "mlstm":
        i = counters["mlstm"]
        st = {"C": cache_sb["mlstm_C"][i], "n": cache_sb["mlstm_n"][i],
              "m": cache_sb["mlstm_m"][i]}
        y, st = mlstm_decode_apply(p["cell"], h, st, n_heads=cfg.n_heads)
        cache_sb = dict(cache_sb)
        for nm in ("C", "n", "m"):
            cache_sb[f"mlstm_{nm}"] = cache_sb[f"mlstm_{nm}"].at[i].set(st[nm])
        counters["mlstm"] += 1
    else:  # slstm
        i = counters["slstm"]
        st = {"h": cache_sb["slstm_h"][i], "c": cache_sb["slstm_c"][i],
              "n": cache_sb["slstm_n"][i], "m": cache_sb["slstm_m"][i]}
        y, st = slstm_decode_apply(p["cell"], h, st, n_heads=cfg.n_heads)
        cache_sb = dict(cache_sb)
        for nm in ("h", "c", "n", "m"):
            cache_sb[f"slstm_{nm}"] = cache_sb[f"slstm_{nm}"].at[i].set(
                st[nm].astype(cache_sb[f"slstm_{nm}"].dtype))
        counters["slstm"] += 1
    x = x + y
    x, _ = _apply_ffn(cfg, spec, p, x, dropless=True)
    return x, cache_sb


def decode_step(cfg: ArchConfig, params, tokens, cache, cache_len):
    """One decoding step.  tokens: (B, 1) int32; cache from init_cache/prefill.

    cache_len may be a scalar (all rows at one depth, the classic path) or
    a (B,) vector (slot-pool decode: every row tracks its own context
    depth; attention writes/attends per row).  Returns (logits (B, vocab),
    new_cache).
    """
    if jnp.ndim(cache_len) > 0:
        assert cfg.rope_theta > 0, \
            "per-row cache_len needs RoPE positions (absolute sinusoidal " \
            "offsets are scalar-only)"
    x = embedding_apply(params["embed"], tokens)
    if cfg.rope_theta == 0:
        x = x + sinusoidal_positions(1, cfg.d_model, offset=cache_len).astype(x.dtype)

    if cfg.encdec is not None:
        def body(h, xs):
            layer, c = xs
            a = _norm_apply(cfg, layer["norm"], h)
            sa, k_new, v_new = attention_decode_apply(
                layer["attn"], a, c["k"], c["v"], cache_len,
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=0.0)
            h = h + sa
            cr = _norm_apply(cfg, layer["cross_norm"], h)
            h = h + cross_attention_decode(layer["cross"], cr, c["ck"], c["cv"],
                                           n_heads=cfg.n_heads,
                                           head_dim=cfg.resolved_head_dim)
            f = _norm_apply(cfg, layer["ffn_norm"], h)
            h = h + mlp_apply(layer["ffn"], f)
            return h, {"k": k_new, "v": v_new, "ck": c["ck"], "cv": c["cv"]}

        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    else:
        specs = sublayer_specs(cfg)

        def body(h, xs):
            sb_params, cache_sb = xs
            counters = {"attn": 0, "mamba": 0, "mlstm": 0, "slstm": 0}
            for spec, p in zip(specs, sb_params):
                h, cache_sb = _decode_sublayer(cfg, spec, p, h, cache_sb,
                                               counters, cache_len)
            return h, cache_sb

        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))

    x = _norm_apply(cfg, params["final_norm"], x)
    logits = (x[:, 0].astype(jnp.float32)
              @ _readout_weight(cfg, params).astype(jnp.float32))
    return logits, new_cache


def prefill(cfg: ArchConfig, params, batch, *, long_context: bool = False,
            max_len: int = 0, lengths=None):
    """Prefill: run the context, return (last-token logits, decode cache).

    batch: tokens (B, T) [+ patches/frames].  The returned cache is ring-
    compacted to cache_window(max_len) capacity (max_len: total context +
    generation budget; defaults to prompt length + 64).

    lengths: optional (B,) valid prompt lengths for a right-padded
    (bucketed) batch.  Pad keys are masked out of attention for every row
    — real-token activations are bit-identical to an unpadded prefill —
    and the returned logits are gathered at each row's last real token.
    Padded prefill is attention-only (recurrent state would integrate the
    pad tokens) and requires the bucket to fit the cache window.
    """
    tokens = batch["tokens"]
    B, T = tokens.shape
    window = cfg.sliding_window
    if lengths is not None:
        assert (cfg.encdec is None and cfg.hybrid is None
                and cfg.xlstm is None and "patches" not in batch), \
            "bucketed (right-padded) prefill supports attention-only " \
            "token batches"
    if cfg.encdec is not None:
        enc_out = _run_encoder(cfg, params, batch["frames"])
        x, _ = _embed_inputs(cfg, params, batch)
        x, caches = _run_decoder_encdec(cfg, params, x, enc_out, collect_cache=True)
        total_T = x.shape[1]
        S = cache_window(cfg, max_len or total_T + 64, long_context=long_context)
        caches = {
            "k": _ring_compact(caches["k"], S, total_T),
            "v": _ring_compact(caches["v"], S, total_T),
            "ck": caches["ck"], "cv": caches["cv"],
        }
    else:
        x, _ = _embed_inputs(cfg, params, batch)
        total_T = x.shape[1]
        eff_window = window or (cache_window(cfg, total_T, long_context=long_context)
                                if long_context else 0)
        x, _, caches = _run_superblocks(cfg, params, x, window=eff_window,
                                        collect_cache=True, dropless=True,
                                        kv_valid_len=lengths)
        S = cache_window(cfg, max_len or total_T + 64, long_context=long_context)
        if lengths is not None:
            assert total_T <= S, \
                f"bucket {total_T} exceeds cache window {S}: ring " \
                "compaction would drop real (non-pad) tokens"
        if "k" in caches:
            caches = dict(caches)
            caches["k"] = _ring_compact(caches["k"], S, total_T)
            caches["v"] = _ring_compact(caches["v"], S, total_T)
    x = _norm_apply(cfg, params["final_norm"], x)
    last = x[:, -1] if lengths is None else x[jnp.arange(B), lengths - 1]
    logits = (last.astype(jnp.float32)
              @ _readout_weight(cfg, params).astype(jnp.float32))
    return logits, caches, total_T


def prefill_chunk(cfg: ArchConfig, params, tokens, cache, depth, *,
                  attend_width: int, last_index=0):
    """Advance a chunked prefill by one token segment.

    tokens: (B, C) the next C prompt tokens (pad-extended past the prompt
    tail); cache: k/v decode cache from `init_cache` whose rows [0, depth)
    already hold the previous segments' keys; depth: () tokens already
    prefilled (traced — one compiled program serves every segment of a
    bucket).  Attention runs the segment's queries against the first
    `attend_width` cache slots via `flash_attention(q_offset=depth)`, so
    a row at absolute position depth+i sees exactly the keys a one-shot
    prefill of the same padded width would show it — segment boundaries
    cannot move a logit by one ULP.  Stale keys past depth+C are causally
    masked (slot index == absolute position for a non-ring prefill).

    Returns (logits (B, vocab) at segment row `last_index`, new cache).
    Chunked prefill is attention-only, like bucketed prefill: recurrent
    state would integrate pad tokens, and SWA rings compact slots away
    from the slot==position layout this relies on.
    """
    assert (cfg.encdec is None and cfg.hybrid is None and cfg.xlstm is None
            and cfg.vlm is None and cfg.moe is None and cfg.rope_theta > 0
            and cfg.sliding_window == 0), \
        f"{cfg.name}: chunked prefill needs a pure-attention dense-FFN " \
        "RoPE decoder (MoE capacity couples rows across the segment)"
    B, C = tokens.shape
    assert attend_width <= cache["k"].shape[3], (attend_width, cache["k"].shape)
    specs = sublayer_specs(cfg)
    x = embedding_apply(params["embed"], tokens)
    depth = jnp.asarray(depth, jnp.int32)
    positions = depth + jnp.arange(C)[None, :]
    hd = cfg.resolved_head_dim

    def body(h, xs):
        sb_params, cache_sb = xs
        counters = {"attn": 0}
        for spec, p in zip(specs, sb_params):
            hn = _norm_apply(cfg, p["norm"], h)
            i = counters["attn"]
            q, k, v = project_qkv(p["attn"], hn, n_heads=cfg.n_heads,
                                  n_kv_heads=cfg.n_kv_heads, head_dim=hd)
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
            kc = jax.lax.dynamic_update_slice_in_dim(
                cache_sb["k"][i], k.astype(cache_sb["k"].dtype), depth,
                axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(
                cache_sb["v"][i], v.astype(cache_sb["v"].dtype), depth,
                axis=1)
            cache_sb = dict(cache_sb)
            cache_sb["k"] = cache_sb["k"].at[i].set(kc)
            cache_sb["v"] = cache_sb["v"].at[i].set(vc)
            counters["attn"] += 1
            out = flash_attention(
                q, jax.lax.slice_in_dim(kc, 0, attend_width, axis=1),
                jax.lax.slice_in_dim(vc, 0, attend_width, axis=1),
                causal=True, q_offset=depth)
            h = h + dense_apply(p["attn"]["wo"],
                                out.reshape(B, C, cfg.n_heads * hd))
            h, _ = _apply_ffn(cfg, spec, p, h, dropless=True)
        return h, cache_sb

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = _norm_apply(cfg, params["final_norm"], x)
    last = x[jnp.arange(B), jnp.asarray(last_index)]
    logits = (last.astype(jnp.float32)
              @ _readout_weight(cfg, params).astype(jnp.float32))
    return logits, new_cache


def _ring_compact(kv, S: int, T: int):
    """(..., B, T, H, D) -> ring buffer (..., B, S, H, D) holding the last S
    tokens at slots (pos % S)."""
    tail = jax.lax.slice_in_dim(kv, max(0, T - S), T, axis=-3)
    if T <= S:
        pad = [(0, 0)] * kv.ndim
        pad[-3] = (0, S - T)
        return jnp.pad(tail, pad)
    return jnp.roll(tail, T % S, axis=-3)
