"""CNNs for the paper-faithful AgileNN reproduction (§6-7).

- feature extractor: 2 conv layers x 24 channels (the paper's exact local
  footprint), stride-2 each -> (B, H/4, W/4, 24) feature maps.
- Local NN: global-average-pool + one dense layer ("minimum complexity").
- Remote NN: MobileNetV2-style inverted-residual stack ("MobileNetV2 with
  the first convolutional layer removed") consuming the offloaded feature
  channels.
- Reference NN: a wider/deeper CNN over the full feature map, pre-trained
  to high accuracy and frozen (the EfficientNet role in §3.1).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.nn.linear import conv2d_apply, conv2d_init, dense_apply, dense_init
from repro.nn.module import split_keys
from repro.nn.norm import groupnorm_apply, groupnorm_init


def _relu6(x):
    return jnp.minimum(jnp.maximum(x, 0.0), 6.0)


# ------------------------------------------------------------- extractor ---
def extractor_init(key, in_ch: int = 3, channels: int = 24, n_layers: int = 2):
    keys = jax.random.split(key, n_layers)
    layers = []
    c = in_ch
    for i in range(n_layers):
        layers.append(conv2d_init(keys[i], c, channels, kernel=3))
        c = channels
    return {"convs": layers}


def extractor_apply(params, x):
    """x: (B, H, W, 3) -> (B, H/2^L, W/2^L, C).  ~paper-scale: 2 convs."""
    for conv in params["convs"]:
        x = _relu6(conv2d_apply(conv, x, stride=2))
    return x


# --------------------------------------------------------------- local NN --
def local_nn_init(key, k: int, n_classes: int, hidden: int = 0):
    kk = split_keys(key, ["fc", "fc2"])
    if hidden:
        return {"fc": dense_init(kk["fc"], k, hidden),
                "fc2": dense_init(kk["fc2"], hidden, n_classes)}
    return {"fc": dense_init(kk["fc"], k, n_classes)}


def local_nn_apply(params, feats_local):
    """feats_local: (B, H, W, k) -> logits (B, n_classes).  GAP + dense."""
    x = jnp.mean(feats_local, axis=(1, 2))
    x = dense_apply(params["fc"], x)
    if "fc2" in params:
        x = dense_apply(params["fc2"], jax.nn.relu(x))
    return x


def local_nn_macs(k: int, n_classes: int, feat_hw: int, hidden: int = 0) -> int:
    """Multiply-accumulate count of the Local NN (for the MCU cost model)."""
    gap = feat_hw * feat_hw * k
    if hidden:
        return gap + k * hidden + hidden * n_classes
    return gap + k * n_classes


# ---------------------------------------------- MobileNetV2-ish remote NN --
def _inverted_residual_init(key, cin: int, cout: int, *, expand: int = 4):
    kk = split_keys(key, ["pw1", "dw", "pw2", "n1", "n2", "n3"])
    mid = cin * expand
    return {
        "pw1": conv2d_init(kk["pw1"], cin, mid, kernel=1, use_bias=False),
        "dw": conv2d_init(kk["dw"], 1, mid, kernel=3, use_bias=False),   # depthwise
        "pw2": conv2d_init(kk["pw2"], mid, cout, kernel=1, use_bias=False),
        "n1": groupnorm_init(mid), "n2": groupnorm_init(mid), "n3": groupnorm_init(cout),
    }


def _inverted_residual_apply(p, x, *, stride: int = 1):
    cin = x.shape[-1]
    mid = p["n1"]["scale"].shape[0]
    h = _relu6(groupnorm_apply(p["n1"], conv2d_apply(p["pw1"], x), groups=8))
    # depthwise conv via feature_group_count
    h = jax.lax.conv_general_dilated(
        h, p["dw"]["w"], window_strides=(stride, stride),
        padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=mid)
    h = _relu6(groupnorm_apply(p["n2"], h, groups=8))
    h = groupnorm_apply(p["n3"], conv2d_apply(p["pw2"], h), groups=8)
    if stride == 1 and h.shape[-1] == cin:
        h = h + x
    return h


def remote_nn_init(key, in_ch: int, n_classes: int, *, width: int = 64,
                   blocks: int = 6):
    kk = split_keys(key, ["stem", "head", "fc"] + [f"b{i}" for i in range(blocks)])
    p = {"stem": conv2d_init(kk["stem"], in_ch, width, kernel=1, use_bias=False),
         "stem_n": groupnorm_init(width)}
    c = width
    blist = []
    for i in range(blocks):
        cout = width * 2 if i >= blocks // 2 else width
        blist.append(_inverted_residual_init(kk[f"b{i}"], c, cout))
        c = cout
    p["blocks"] = blist
    p["fc"] = dense_init(kk["fc"], c, n_classes)
    return p


def remote_nn_apply(params, feats):
    """feats: (B, H, W, C_remote) -> logits."""
    x = _relu6(groupnorm_apply(params["stem_n"], conv2d_apply(params["stem"], feats), groups=8))
    n = len(params["blocks"])
    for i, b in enumerate(params["blocks"]):
        stride = 2 if i == n // 2 else 1
        x = _inverted_residual_apply(b, x, stride=stride)
    x = jnp.mean(x, axis=(1, 2))
    return dense_apply(params["fc"], x)


# ----------------------------------------------------------- reference NN --
def reference_nn_init(key, in_ch: int, n_classes: int, *, width: int = 96,
                      blocks: int = 8):
    return remote_nn_init(key, in_ch, n_classes, width=width, blocks=blocks)


reference_nn_apply = remote_nn_apply


# ------------------------------------------------------------ cost model ---
def conv_macs(h: int, w: int, kernel: int, cin: int, cout: int,
              stride: int = 1) -> int:
    return (h // stride) * (w // stride) * kernel * kernel * cin * cout


def extractor_macs(image_size: int, in_ch: int = 3, channels: int = 24,
                   n_layers: int = 2) -> int:
    total, s, c = 0, image_size, in_ch
    for _ in range(n_layers):
        total += conv_macs(s, s, 3, c, channels, stride=2)
        s //= 2
        c = channels
    return total
