"""XAI feature-attribution tools (paper §2.2, §7.7).

Both tools attribute a model's output to the *extracted feature channels*
(not raw pixels): given features F (B, ..., C) and a prediction function
`predict(features) -> confidence scores (B, n_classes)`, they return a
per-channel importance map the same shape as F.

Integrated Gradients [Sundararajan et al. 2017]:
    IG_i = (F_i - F0_i) * mean_{s=1..m} d predict(F0 + s/m (F - F0))_y / dF_i
Gradient Saliency: |d predict(F)_y / dF_i|.

The interpolation axis is evaluated with lax.scan (constant HLO size in
the number of steps) and the whole evaluation is batched/vmappable so a
pod can shard it over data — this is the training-time cost the paper
pays on a single GPU (its 3-4x epoch-time increase, §7.1).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def _target_scores(predict: Callable, feats, targets):
    """Confidence score of the target class per sample."""
    logits = predict(feats)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.take_along_axis(probs, targets[:, None], axis=-1)[:, 0]


def gradient_saliency(predict: Callable, feats, targets) -> jnp.ndarray:
    """|d score_y / d feats| — one gradient pass."""
    def score_sum(f):
        return jnp.sum(_target_scores(predict, f, targets))
    g = jax.grad(score_sum)(feats)
    return jnp.abs(g.astype(jnp.float32))


def integrated_gradients(predict: Callable, feats, targets, *,
                         steps: int = 16, baseline=None) -> jnp.ndarray:
    """Path integral of gradients from `baseline` (default zeros) to feats.

    Accumulates with lax.scan over the interpolation axis; `steps`
    trades accuracy for cost (paper: 20-100 gradient passes; the knob is
    AgileSpec.ig_steps).
    """
    if baseline is None:
        baseline = jnp.zeros_like(feats)
    delta = feats - baseline

    def score_sum(f):
        return jnp.sum(_target_scores(predict, f, targets))

    grad_fn = jax.grad(score_sum)

    def body(acc, i):
        alpha = (i.astype(jnp.float32) + 1.0) / steps
        g = grad_fn(baseline + alpha * delta)
        return acc + g.astype(jnp.float32), None

    acc0 = jnp.zeros(feats.shape, jnp.float32)
    acc, _ = jax.lax.scan(body, acc0, jnp.arange(steps))
    return jnp.abs(delta.astype(jnp.float32) * acc / steps)


def channel_importance(attr: jnp.ndarray) -> jnp.ndarray:
    """Aggregate an attribution map (B, ..., C) to per-channel importance
    (B, C), normalized to sum 1 (the paper's 'normalized importance')."""
    reduce_axes = tuple(range(1, attr.ndim - 1))
    imp = jnp.sum(attr, axis=reduce_axes) if reduce_axes else attr
    total = jnp.sum(imp, axis=-1, keepdims=True)
    return imp / jnp.maximum(total, 1e-12)


def evaluate_importance(predict: Callable, feats, targets, *,
                        method: str = "ig", steps: int = 16) -> jnp.ndarray:
    """Normalized per-channel importance (B, C).  method: 'ig' | 'saliency'."""
    if method == "ig":
        attr = integrated_gradients(predict, feats, targets, steps=steps)
    elif method == "saliency":
        attr = gradient_saliency(predict, feats, targets)
    else:
        raise ValueError(f"unknown XAI method: {method}")
    return channel_importance(attr)
