"""Algorithm 1 (paper §5): select the k initial feature channels.

For every training sample, evaluate feature importance with the XAI tool
(against the pre-trained reference NN) and count, per channel, how often
the channel hosts one of the sample's top-k features.  The k channels with
the highest likelihood become the initial local channels; the training-
time mapping layer then permutes them into the first k slots.
"""
from __future__ import annotations

from typing import Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np


def topk_channel_counts(importance: jnp.ndarray, k: int) -> jnp.ndarray:
    """importance: (B, C) -> per-channel counts of top-k membership (C,)."""
    C = importance.shape[-1]
    _, idx = jax.lax.top_k(importance, k)          # (B, k)
    onehot = jax.nn.one_hot(idx, C, dtype=jnp.float32)
    return jnp.sum(onehot, axis=(0, 1))


def select_initial_channels(
        extractor: Callable, importance_fn: Callable,
        batches: Iterable, k: int) -> np.ndarray:
    """Run Algorithm 1 over a dataset.

    extractor(batch) -> features; importance_fn(features, batch) -> (B, C)
    normalized importances.  Returns the k selected channel indices, ranked
    by likelihood p_c (ties broken by channel id, like argsort).
    """
    counts = None
    total = 0
    for batch in batches:
        feats = extractor(batch)
        imp = importance_fn(feats, batch)
        c = topk_channel_counts(imp, k)
        counts = c if counts is None else counts + c
        total += imp.shape[0]
    p = np.asarray(counts) / max(total, 1)         # p_c, line 9
    ranking = np.argsort(-p, kind="stable")        # line 10
    return ranking[:k]                             # line 11


def build_mapping_permutation(selected: np.ndarray, n_channels: int) -> np.ndarray:
    """Permutation that moves `selected` channels to the first k slots
    (training-time mapping layer, §5 Figure 12; discarded after training
    by folding it into the extractor's final conv weights)."""
    selected = list(selected)
    rest = [c for c in range(n_channels) if c not in selected]
    return np.array(selected + rest, dtype=np.int32)


def permute_reference_stem(ref_params: dict, perm: np.ndarray) -> dict:
    """Permute the reference NN's stem input channels so it consumes
    *mapped* features: new ref(mapped_feats) == old ref(raw_feats).
    (mapped[c] = raw[perm[c]], so stem weight channel c must become the old
    channel perm[c].)"""
    out = dict(ref_params)
    stem = dict(out["stem"])
    stem["w"] = ref_params["stem"]["w"][:, :, perm, :]
    out["stem"] = stem
    return out


def fold_permutation_into_conv(conv_params: dict, perm: np.ndarray) -> dict:
    """Discard the mapping layer by permuting the extractor's last conv's
    output channels (weights (kh, kw, cin, cout), bias (cout,)) — after
    this the extractor emits features already in mapped order, at zero
    runtime cost (the paper's 'mapping layer is discarded')."""
    out = dict(conv_params)
    out["w"] = conv_params["w"][..., perm]
    if "b" in conv_params:
        out["b"] = conv_params["b"][perm]
    return out
