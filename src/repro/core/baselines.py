"""Comparison baselines (paper §7): edge-only, MCUNet-proxy (local-only),
DeepCOD-style learned sparse encoder, SPINN-style early-exit partitioning.

Each baseline exposes init / train-step pieces + a `runtime_cost` that uses
the same DeviceModel accounting as AgileNN, so Figure 16/19/22/23-style
comparisons are apples-to-apples.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress.lzw import compress_payload, pack_indices
from repro.compress.quantize import (
    hard_indices,
    quantization_bits,
    quantize_ste,
    quantizer_init,
)
from repro.configs.agilenn_cifar import AgileNNConfig
from repro.core.agile import cross_entropy
from repro.models.cnn import (
    conv_macs,
    extractor_apply,
    extractor_init,
    extractor_macs,
    local_nn_macs,
    remote_nn_apply,
    remote_nn_init,
)
from repro.nn.linear import conv2d_apply, conv2d_init, dense_apply, dense_init
from repro.nn.module import split_keys
from repro.serve.device_model import DeviceModel, InferenceCost


# ========================================================== edge-only ======
def edge_only_payload(images: np.ndarray) -> int:
    """LZW on the raw uint8 image; returns total bytes for the batch."""
    arr = np.asarray(images)
    arr = np.clip((arr - arr.min()) / max(float(np.ptp(arr)), 1e-6) * 255,
                  0, 255).astype(np.uint8)
    total = 0
    for b in range(arr.shape[0]):
        nbytes, _ = compress_payload(arr[b].tobytes())
        total += nbytes
    return total


def edge_only_cost(cfg: AgileNNConfig, images, *, remote_macs: float,
                   device: DeviceModel | None = None) -> InferenceCost:
    device = device or DeviceModel(cpu_hz=cfg.mcu_hz, link_bps=cfg.link_bps)
    payload = edge_only_payload(images) / images.shape[0]
    return InferenceCost(
        local_compute_s=device.compute_time(0.0), tx_s=device.tx_time(payload),
        server_s=device.server_time(remote_macs), payload_bytes=payload,
        local_macs=0.0, remote_macs=remote_macs)


# ================================================== MCUNet proxy (local) ===
def mcunet_init(key, cfg: AgileNNConfig, *, width: int = 32, blocks: int = 4):
    """A NAS-proxy compact CNN executed fully on-device."""
    kk = split_keys(key, ["stem", "body", "fc"])
    p = {"stem": conv2d_init(kk["stem"], 3, width)}
    p["body"] = remote_nn_init(kk["body"], width, cfg.n_classes,
                               width=width, blocks=blocks)
    return p


def mcunet_apply(params, images):
    x = jax.nn.relu(conv2d_apply(params["stem"], images, stride=2))
    return remote_nn_apply(params["body"], x)


def mcunet_macs(cfg: AgileNNConfig, *, width: int = 32, blocks: int = 4) -> int:
    s = cfg.image_size
    total = conv_macs(s, s, 3, 3, width, stride=2)
    s //= 2
    # same structure as remote_nn_macs but starting at `width` input
    c = width
    total += s * s * c * width
    for i in range(blocks):
        cout = width * 2 if i >= blocks // 2 else width
        stride = 2 if i == blocks // 2 else 1
        mid = c * 4
        total += s * s * c * mid
        s //= stride
        total += s * s * mid * 9
        total += s * s * mid * cout
        c = cout
    return total + c * cfg.n_classes


def mcunet_cost(cfg: AgileNNConfig, *, device: DeviceModel | None = None,
                width: int = 32, blocks: int = 4) -> InferenceCost:
    device = device or DeviceModel(cpu_hz=cfg.mcu_hz, link_bps=cfg.link_bps)
    macs = mcunet_macs(cfg, width=width, blocks=blocks)
    return InferenceCost(local_compute_s=device.compute_time(macs), tx_s=0.0,
                         server_s=0.0, payload_bytes=0.0, local_macs=macs,
                         remote_macs=0.0)


# ================================================== DeepCOD-style encoder ==
def deepcod_init(key, cfg: AgileNNConfig, *, code_channels: int = 0):
    """Local learned encoder (extractor + 1x1 bottleneck) -> quantize ->
    remote decoder/classifier; trained end-to-end with an L1 sparsity
    penalty on the code (the paper's 'sparsity constraint').

    code_channels defaults to the same transmitted-channel count as
    AgileNN (C - k) so the Table-2 byte comparison is apples-to-apples
    (the paper keeps accuracy comparable and measures bytes)."""
    code_channels = code_channels or (cfg.extractor_channels - cfg.agile.k)
    kk = split_keys(key, ["ex", "bottleneck", "remote"])
    return {
        "ex": extractor_init(kk["ex"], channels=cfg.extractor_channels,
                             n_layers=cfg.extractor_layers),
        "bottleneck": conv2d_init(kk["bottleneck"], cfg.extractor_channels,
                                  code_channels, kernel=1),
        "remote": remote_nn_init(kk["remote"], code_channels, cfg.n_classes,
                                 width=cfg.remote_width, blocks=cfg.remote_blocks),
        "quant": quantizer_init(n_centers=8),
    }


def deepcod_code(params, images):
    feats = extractor_apply(params["ex"], images)
    return conv2d_apply(params["bottleneck"], feats)


def deepcod_forward(params, images, *, train: bool = True):
    code = deepcod_code(params, images)
    code_q = quantize_ste(params["quant"], code) if train else \
        jnp.take(params["quant"]["centers"], hard_indices(params["quant"], code))
    logits = remote_nn_apply(params["remote"], code_q)
    return logits, code


def deepcod_loss(params, images, labels, *, sparsity_weight: float = 1e-3):
    logits, code = deepcod_forward(params, images, train=True)
    ce = cross_entropy(logits, labels)
    l1 = jnp.mean(jnp.abs(code))
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return ce + sparsity_weight * l1, {"ce": ce, "l1": l1, "accuracy": acc}


def deepcod_payload(params, images) -> int:
    idx = np.asarray(hard_indices(params["quant"], deepcod_code(params, images)))
    bits = quantization_bits(params["quant"]["centers"].shape[0])
    total = 0
    for b in range(idx.shape[0]):
        packed = pack_indices(idx[b], bits)
        nbytes, _ = compress_payload(packed)
        total += nbytes
    return total


def deepcod_local_macs(cfg: AgileNNConfig, code_channels: int = 0) -> int:
    code_channels = code_channels or (cfg.extractor_channels - cfg.agile.k)
    feat_hw = cfg.image_size // (2 ** cfg.extractor_layers)
    return (extractor_macs(cfg.image_size, 3, cfg.extractor_channels,
                           cfg.extractor_layers)
            + feat_hw * feat_hw * cfg.extractor_channels * code_channels)


def deepcod_cost(cfg: AgileNNConfig, params, images, *, remote_macs: float,
                 device: DeviceModel | None = None,
                 code_channels: int = 0) -> InferenceCost:
    device = device or DeviceModel(cpu_hz=cfg.mcu_hz, link_bps=cfg.link_bps)
    payload = deepcod_payload(params, images) / images.shape[0]
    macs = deepcod_local_macs(cfg, code_channels)
    return InferenceCost(local_compute_s=device.compute_time(macs),
                         tx_s=device.tx_time(payload),
                         server_s=device.server_time(remote_macs),
                         payload_bytes=payload, local_macs=macs,
                         remote_macs=remote_macs)


# ===================================================== SPINN-style exits ===
def spinn_init(key, cfg: AgileNNConfig):
    """Partitioned net with a local early-exit head: local = extractor +
    exit classifier; remote = full classifier on (quantized) features."""
    kk = split_keys(key, ["ex", "exit", "remote"])
    return {
        "ex": extractor_init(kk["ex"], channels=cfg.extractor_channels,
                             n_layers=cfg.extractor_layers),
        "exit": dense_init(kk["exit"], cfg.extractor_channels, cfg.n_classes),
        "remote": remote_nn_init(kk["remote"], cfg.extractor_channels,
                                 cfg.n_classes, width=cfg.remote_width,
                                 blocks=cfg.remote_blocks),
        "quant": quantizer_init(n_centers=8),
    }


def spinn_forward(params, images, *, train: bool = True):
    feats = extractor_apply(params["ex"], images)
    exit_logits = dense_apply(params["exit"], jnp.mean(feats, axis=(1, 2)))
    fq = quantize_ste(params["quant"], feats) if train else \
        jnp.take(params["quant"]["centers"], hard_indices(params["quant"], feats))
    remote_logits = remote_nn_apply(params["remote"], fq)
    return exit_logits, remote_logits, feats


def spinn_loss(params, images, labels):
    exit_logits, remote_logits, _ = spinn_forward(params, images, train=True)
    ce = cross_entropy(remote_logits, labels) + 0.5 * cross_entropy(exit_logits, labels)
    acc = jnp.mean((jnp.argmax(remote_logits, -1) == labels).astype(jnp.float32))
    return ce, {"accuracy": acc}


def spinn_cost(cfg: AgileNNConfig, params, images, *, remote_macs: float,
               exit_threshold: float = 0.9,
               device: DeviceModel | None = None) -> InferenceCost:
    """Expected cost: early-exit samples stay local; the rest offload."""
    device = device or DeviceModel(cpu_hz=cfg.mcu_hz, link_bps=cfg.link_bps)
    exit_logits, _, feats = spinn_forward(params, images, train=False)
    conf = jnp.max(jax.nn.softmax(exit_logits, -1), axis=-1)
    stay = np.asarray(conf >= exit_threshold)
    idx = np.asarray(hard_indices(params["quant"], feats))
    bits = quantization_bits(params["quant"]["centers"].shape[0])
    payload = 0
    for b in range(idx.shape[0]):
        if not stay[b]:
            packed = pack_indices(idx[b], bits)
            nbytes, _ = compress_payload(packed)
            payload += nbytes
    feat_hw = cfg.image_size // (2 ** cfg.extractor_layers)
    macs = (extractor_macs(cfg.image_size, 3, cfg.extractor_channels,
                           cfg.extractor_layers)
            + local_nn_macs(cfg.extractor_channels, cfg.n_classes, feat_hw))
    offload_frac = 1.0 - float(stay.mean())
    per_payload = payload / images.shape[0]
    return InferenceCost(local_compute_s=device.compute_time(macs),
                         tx_s=device.tx_time(per_payload),
                         server_s=device.server_time(remote_macs) * offload_frac,
                         payload_bytes=per_payload, local_macs=macs,
                         remote_macs=remote_macs * offload_frac)


# ------------------------------------------------------- generic trainer ---
def train_baseline(loss_fn, params, data, *, steps: int, batch_size: int = 32,
                   lr: float = 0.02, seed_base: int = 50_000):
    """SGD loop shared by the DeepCOD/SPINN/MCUNet baselines."""
    from repro.optim.sgd import sgd_init, sgd_update
    opt = sgd_init(params)

    @jax.jit
    def step(p, o, images, labels, lr):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            p, images, labels)
        p, o = sgd_update(p, grads, o, lr=lr)
        return p, o, loss, metrics

    metrics = {}
    for i in range(steps):
        images, labels = data.batch(batch_size, seed=seed_base + i)
        cur_lr = lr * (0.1 if i > steps * 0.7 else 1.0)
        params, opt, loss, metrics = step(params, opt, images, labels, cur_lr)
    return params, {k: float(v) for k, v in metrics.items()}
