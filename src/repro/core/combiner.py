"""Local/remote prediction combination (paper §3.3).

final = alpha * local + (1 - alpha) * remote, with
alpha = sigmoid(w / T): w trainable, T in [4, 8] softens the sigmoid so
training cannot collapse alpha to 0/1 and starve the Local NN.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def combiner_init(init_alpha: float = 0.5, temperature: float = 6.0):
    """Parameterize so sigmoid(w/T) == init_alpha at start."""
    w = temperature * jnp.log(init_alpha / (1.0 - init_alpha)) if init_alpha != 0.5 else 0.0
    return {"w": jnp.asarray(w, jnp.float32)}


def alpha_value(params, temperature: float) -> jnp.ndarray:
    return jax.nn.sigmoid(params["w"] / temperature)


def combine_predictions(params, local_logits, remote_logits, *,
                        temperature: float = 6.0, alpha_override=None):
    """Point-to-point weighted sum over aligned class channels.  The
    runtime may override alpha (paper: user-tunable at deployment)."""
    a = alpha_override if alpha_override is not None else alpha_value(params, temperature)
    return a * local_logits + (1.0 - a) * remote_logits
