"""AgileNN joint model (paper Figure 5): extractor + Local NN + Remote NN
+ combiner + quantizer, with the XAI-driven skewness-manipulation loss.

Parameter tree:
  extractor   2-conv feature extractor (deployed on the weak device)
  local       GAP + dense Local NN (deployed on the weak device)
  remote      MobileNetV2-style Remote NN (deployed on the server/pod)
  combiner    alpha = sigmoid(w / T)
  quant       learned scalar codebook for the offloaded channels
  mapping     channel permutation (training-time only; folded into the
              extractor's last conv for deployment)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress.quantize import (
    dequantize,
    hard_indices,
    quantize_ste,
    quantizer_init,
)
from repro.configs.agilenn_cifar import AgileNNConfig
from repro.core.combiner import alpha_value, combine_predictions, combiner_init
from repro.core.skewness import combined_loss
from repro.core.splitter import merge_features, split_features
from repro.kernels.offload_fused.ops import fused_offload
from repro.core.xai import evaluate_importance
from repro.models.cnn import (
    extractor_apply,
    extractor_init,
    local_nn_apply,
    local_nn_init,
    reference_nn_apply,
    remote_nn_apply,
    remote_nn_init,
)
from repro.nn.module import split_keys


def init_agile_params(cfg: AgileNNConfig, key, *, extractor_params=None) -> dict:
    """extractor_params: pre-trained weights from the pre-processing stage
    (§3.2/§5); falls back to fresh init."""
    C, k = cfg.extractor_channels, cfg.agile.k
    kk = split_keys(key, ["extractor", "local", "remote", "combiner"])
    return {
        "extractor": extractor_params if extractor_params is not None else
        extractor_init(kk["extractor"], channels=C, n_layers=cfg.extractor_layers),
        "local": local_nn_init(kk["local"], k, cfg.n_classes, hidden=cfg.local_hidden),
        "remote": remote_nn_init(kk["remote"], C - k, cfg.n_classes,
                                 width=cfg.remote_width, blocks=cfg.remote_blocks),
        "combiner": combiner_init(0.5, cfg.agile.alpha_temperature),
        "quant": quantizer_init(n_centers=8),
        "mapping": jnp.arange(C, dtype=jnp.int32),   # identity until Alg. 1 runs
    }


def extract_features(cfg: AgileNNConfig, params, images):
    """Extractor + (training-time) mapping permutation."""
    feats = extractor_apply(params["extractor"], images)
    return jnp.take(feats, params["mapping"], axis=-1)


def _static_perm(mapping):
    """The deployed permutation as a static tuple, or None when `mapping`
    is a tracer (training: the fused online kernel is bypassed)."""
    if isinstance(mapping, jax.core.Tracer):
        return None
    return tuple(int(p) for p in np.asarray(mapping))


def agile_forward(cfg: AgileNNConfig, params, images, *, train: bool = True,
                  quantize: bool = True, alpha_override=None,
                  use_fused: bool = True):
    """Full split pipeline.  Returns (combined_logits, internals dict).

    The deployment path (train=False, quantize=True) runs the fused
    one-pass permute->split->quantize offload kernel whenever the mapping
    is concrete; training keeps the differentiable two-pass composition.
    """
    perm = (_static_perm(params["mapping"])
            if use_fused and not train and quantize else None)
    if perm is not None:
        raw = extractor_apply(params["extractor"], images)
        f_local, f_remote, _, f_remote_q = fused_offload(
            raw, params["quant"]["centers"], perm=perm, k=cfg.agile.k)
        feats = merge_features(f_local, f_remote)
    else:
        feats = extract_features(cfg, params, images)
        f_local, f_remote = split_features(feats, cfg.agile.k)
        if quantize:
            if train:
                f_remote_q = quantize_ste(params["quant"], f_remote)
            else:
                f_remote_q = dequantize(params["quant"],
                                        hard_indices(params["quant"], f_remote))
        else:
            f_remote_q = f_remote
    local_logits = local_nn_apply(params["local"], f_local)
    remote_logits = remote_nn_apply(params["remote"], f_remote_q)
    logits = combine_predictions(params["combiner"], local_logits, remote_logits,
                                 temperature=cfg.agile.alpha_temperature,
                                 alpha_override=alpha_override)
    return logits, {
        "features": feats,
        "local_logits": local_logits,
        "remote_logits": remote_logits,
        "alpha": alpha_value(params["combiner"], cfg.agile.alpha_temperature),
    }


def reference_predict_fn(cfg: AgileNNConfig, ref_params) -> Callable:
    """predict(features) -> logits, for the XAI tool (reference NN consumes
    the full extracted feature map, §3.1)."""
    def predict(feats):
        return reference_nn_apply(ref_params, feats)
    return predict


def batch_importance(cfg: AgileNNConfig, ref_params, feats, labels, *,
                     method: str = "ig"):
    """Normalized channel importance (B, C) + validity weights (B,).

    Per §3.1 the reference NN's output is only used when it predicts the
    training label correctly; other samples get weight 0 in the skewness
    losses.
    """
    predict = reference_predict_fn(cfg, ref_params)
    imp = evaluate_importance(predict, feats, labels, method=method,
                              steps=cfg.agile.ig_steps)
    ref_pred = jnp.argmax(predict(feats), axis=-1)
    valid = (ref_pred == labels).astype(jnp.float32)
    return imp, valid


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def agile_loss(cfg: AgileNNConfig, params, ref_params, images, labels, *,
               xai_method: str = "ig", ordering: str = "disorder",
               lam: "float | None" = None):
    """The unified training loss (§4.2).  Returns (loss, metrics).

    ordering/lam overrides feed the Figure-9/Figure-10 ablations."""
    logits, internals = agile_forward(cfg, params, images, train=True)
    pred_loss = cross_entropy(logits, labels)

    feats = internals["features"]
    # reference/XAI path must not backprop into the reference NN; gradients
    # DO flow into the extractor through `feats` (that is how skewness is
    # manipulated).
    imp, valid = batch_importance(cfg, jax.lax.stop_gradient(ref_params),
                                  feats, labels, method=xai_method)
    # zero-out invalid rows by replacing with an 'ideal' importance that
    # produces zero loss: all mass on channel 0.
    C = imp.shape[-1]
    ideal = jax.nn.one_hot(jnp.zeros((imp.shape[0],), jnp.int32), C)
    imp_eff = jnp.where(valid[:, None] > 0, imp, ideal)

    total, metrics = combined_loss(pred_loss, imp_eff, k=cfg.agile.k,
                                   rho=cfg.agile.rho,
                                   lam=cfg.agile.lam if lam is None else lam,
                                   ordering=ordering)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    metrics.update(accuracy=acc, alpha=internals["alpha"],
                   xai_valid_fraction=jnp.mean(valid))
    return total, metrics


def device_forward(cfg: AgileNNConfig, params, images, *, use_fused: bool = True):
    """The device half of the deployment pipeline, batched.

    Runs extractor -> fused permute/split/quantize -> Local NN for a whole
    batch of images WITHOUT touching the Remote-NN weights (which live on
    the gateway side of the link).  Returns
    (local_logits (B, n_classes), f_remote (B, H, W, C-k), idx) where
    ``idx`` are the full-codebook quantization indices the static offload
    configuration transmits and ``f_remote`` the pre-quantization remote
    features an adaptive rate controller re-quantizes at reduced bit
    widths.  Bit-identical to the device-side tensors of `agile_forward`'s
    deployment path (the offload gateway's parity anchor)."""
    perm = _static_perm(params["mapping"]) if use_fused else None
    if perm is not None:
        raw = extractor_apply(params["extractor"], images)
        f_local, f_remote, idx, _ = fused_offload(
            raw, params["quant"]["centers"], perm=perm, k=cfg.agile.k)
    else:
        feats = extract_features(cfg, params, images)
        f_local, f_remote = split_features(feats, cfg.agile.k)
        idx = hard_indices(params["quant"], f_remote)
    local_logits = local_nn_apply(params["local"], f_local)
    return local_logits, f_remote, idx


@partial(jax.jit, static_argnames=("perm", "k"))
def _device_forward_jit(params, images, *, perm: tuple, k: int):
    raw = extractor_apply(params["extractor"], images)
    f_local, f_remote, idx, _ = fused_offload(
        raw, params["quant"]["centers"], perm=perm, k=k)
    return local_nn_apply(params["local"], f_local), f_remote, idx


def device_forward_fn(cfg: AgileNNConfig, params) -> Callable:
    """Jit-compiled `device_forward` with the deployed channel
    permutation folded in as a static constant (the fleet's batched
    device pass: one compiled program for any fleet-wide image batch,
    cached module-wide so repeated fleet builds don't recompile).

    `params["mapping"]` must be concrete — inside a jit the mapping is a
    tracer and the fused one-pass kernel could not be selected."""
    perm = _static_perm(params["mapping"])
    assert perm is not None, "device_forward_fn needs a concrete mapping"
    return partial(_device_forward_jit, perm=perm, k=cfg.agile.k)


def remote_forward(cfg: AgileNNConfig, params, f_remote_q, local_logits, *,
                   alpha_override=None):
    """The gateway/server half: Remote NN over dequantized offloaded
    features + alpha-combine with the device's Local-NN logits.

    Composing `device_forward` -> dequantize -> `remote_forward` is
    bit-identical to `agile_forward(train=False)` (the gateway jits this
    function once per feature-batch shape)."""
    remote_logits = remote_nn_apply(params["remote"], f_remote_q)
    return combine_predictions(params["combiner"], local_logits, remote_logits,
                               temperature=cfg.agile.alpha_temperature,
                               alpha_override=alpha_override)


@partial(jax.jit, static_argnames=("temperature",))
def remote_forward_jit(params, f_remote_q, local_logits, *,
                       temperature: float):
    """Module-level compiled `remote_forward` (one compile per
    (batch shape, temperature) shared across every gateway instance —
    a per-instance `jax.jit` closure would re-trace and re-compile for
    each fleet run)."""
    remote_logits = remote_nn_apply(params["remote"], f_remote_q)
    return combine_predictions(params["combiner"], local_logits,
                               remote_logits, temperature=temperature)


def agile_predict(cfg: AgileNNConfig, params, images, *, alpha_override=None):
    """Deployment-path prediction (hard quantization)."""
    logits, internals = agile_forward(cfg, params, images, train=False,
                                      alpha_override=alpha_override)
    return logits, internals


def offload_payload_arrays(cfg: AgileNNConfig, params, images, *,
                           use_fused: bool = True):
    """What the device actually transmits: hard quantization indices of the
    less-important channels (to be bit-packed + LZW'd by the runtime).

    use_fused=False forces the seed two-pass path (kept as the parity
    oracle for the fused kernel)."""
    perm = _static_perm(params["mapping"]) if use_fused else None
    if perm is not None:
        raw = extractor_apply(params["extractor"], images)
        _, _, idx, _ = fused_offload(raw, params["quant"]["centers"],
                                     perm=perm, k=cfg.agile.k)
        return idx
    feats = extract_features(cfg, params, images)
    _, f_remote = split_features(feats, cfg.agile.k)
    return hard_indices(params["quant"], f_remote)
