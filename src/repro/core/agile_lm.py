"""AgileNN split serving for the LM backbones (DESIGN.md §4).

The paper's technique applied to the assigned architectures: a weak edge
device runs a *lightweight token-feature extractor* (embedding + one
gated projection); the extractor's d_agile feature channels are
importance-skewed during training (same Eq.1/2 losses, IG against a
reference LM) so the top-k channels feed a tiny on-device next-token
head, while the remaining channels are quantized + compressed and
offloaded to the Remote NN — the full backbone on the pod — whose logits
are alpha-combined with the local head's.

This mirrors Figure 5 one-to-one at the token level:
  extractor   embed -> silu-gated dense -> (B, T, C_agile)
  Local NN    last-token top-k channels -> dense -> vocab logits
  Remote NN   full backbone consuming remote-channel features projected
              back into d_model (plus the raw tokens' embeddings — the
              split is on the *extractor features*, as in the paper)
  reference   a frozen (tracked) LM head over the extractor features
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.compress.quantize import (
    dequantize,
    hard_indices,
    quantize_ste,
    quantizer_init,
)
from repro.configs.base import ArchConfig
from repro.core.combiner import alpha_value, combine_predictions, combiner_init
from repro.core.skewness import combined_loss
from repro.core.splitter import split_features
from repro.core.xai import evaluate_importance
from repro.models import backbone as bb
from repro.nn.activations import silu
from repro.nn.linear import dense_apply, dense_init, embedding_apply, embedding_init
from repro.nn.module import split_keys


def init_agile_lm_params(cfg: ArchConfig, key) -> dict:
    """Extractor/local/combiner/quantizer + remote backbone + reference."""
    a = cfg.agile
    C = a.extractor_channels
    kk = split_keys(key, ["embed", "gate", "proj", "local", "remote",
                          "ref", "back"])
    return {
        "extractor": {
            "embed": embedding_init(kk["embed"], cfg.vocab, C),
            "gate": dense_init(kk["gate"], C, C, use_bias=True),
            "proj": dense_init(kk["proj"], C, C, use_bias=False),
        },
        "local": dense_init(kk["local"], a.k, cfg.vocab, use_bias=False),
        "remote_in": dense_init(kk["remote"], C - a.k, cfg.d_model,
                                use_bias=False),
        "reference": dense_init(kk["ref"], C, cfg.vocab, use_bias=False),
        "combiner": combiner_init(0.5, a.alpha_temperature),
        "quant": quantizer_init(n_centers=8),
        "backbone": bb.init_params(cfg, kk["back"]),
    }


def extract_token_features(params, tokens):
    """The on-device extractor: (B, T) -> (B, T, C_agile)."""
    e = params["extractor"]
    x = embedding_apply(e["embed"], tokens)
    return dense_apply(e["proj"], x * silu(dense_apply(e["gate"], x)))


def agile_lm_forward(cfg: ArchConfig, params, tokens, *, train: bool = True,
                     alpha_override=None):
    """Next-token logits for the LAST position via the split pipeline.

    Returns (logits (B, vocab), internals)."""
    a = cfg.agile
    feats = extract_token_features(params, tokens)          # (B, T, C)
    f_local, f_remote = split_features(feats, a.k)
    if train:
        f_remote_q = quantize_ste(params["quant"], f_remote)
    else:
        f_remote_q = dequantize(params["quant"],
                                hard_indices(params["quant"], f_remote))
    # local head: tiny dense on the last token's top-k channels
    local_logits = dense_apply(params["local"], f_local[:, -1])
    # remote: backbone consumes token embeddings + projected remote features
    h = bb.forward_hidden(cfg, {**params["backbone"]},
                          {"tokens": tokens})
    h = h + dense_apply(params["remote_in"], f_remote_q)
    w = bb._readout_weight(cfg, params["backbone"])
    remote_logits = h[:, -1].astype(jnp.float32) @ w.astype(jnp.float32)
    logits = combine_predictions(params["combiner"], local_logits,
                                 remote_logits,
                                 temperature=a.alpha_temperature,
                                 alpha_override=alpha_override)
    return logits, {
        "features": feats,
        "local_logits": local_logits,
        "remote_logits": remote_logits,
        "alpha": alpha_value(params["combiner"], a.alpha_temperature),
    }


def _token_importance(cfg: ArchConfig, ref_w, feats, targets, *,
                      method: str = "ig", steps: int = 8):
    """Channel importance of the LAST token's features under the reference
    head (a linear readout over extractor features — cheap and exact for
    IG with few steps)."""
    last = feats[:, -1]

    def predict(f):
        return dense_apply(ref_w, f)

    return evaluate_importance(predict, last, targets, method=method,
                               steps=steps)


def agile_lm_loss(cfg: ArchConfig, params, tokens, labels_last, *,
                  xai_method: str = "ig"):
    """Unified loss on next-token prediction of the final position.

    tokens: (B, T); labels_last: (B,) the T+1-th token.
    """
    a = cfg.agile
    logits, internals = agile_lm_forward(cfg, params, tokens, train=True)
    logp = jax.nn.log_softmax(logits, axis=-1)
    pred_loss = -jnp.mean(
        jnp.take_along_axis(logp, labels_last[:, None], axis=-1))

    ref_w = jax.lax.stop_gradient(params["reference"])
    imp = _token_importance(cfg, ref_w, internals["features"], labels_last,
                            method=xai_method, steps=a.ig_steps)
    ref_logits = dense_apply(ref_w, internals["features"][:, -1])
    valid = (jnp.argmax(ref_logits, -1) == labels_last).astype(jnp.float32)
    ideal = jax.nn.one_hot(jnp.zeros((imp.shape[0],), jnp.int32),
                           imp.shape[-1])
    imp_eff = jnp.where(valid[:, None] > 0, imp, ideal)
    total, metrics = combined_loss(pred_loss, imp_eff, k=a.k, rho=a.rho,
                                   lam=a.lam)
    # train the reference head alongside (tracking; stop-grad features)
    ref_ce = -jnp.mean(jnp.take_along_axis(
        jax.nn.log_softmax(dense_apply(
            params["reference"],
            jax.lax.stop_gradient(internals["features"][:, -1]))),
        labels_last[:, None], axis=-1))
    total = total + 0.3 * ref_ce
    acc = jnp.mean((jnp.argmax(logits, -1) == labels_last).astype(jnp.float32))
    metrics.update(accuracy=acc, alpha=internals["alpha"],
                   xai_valid_fraction=jnp.mean(valid), ref_ce=ref_ce)
    return total, metrics


def offload_payload_bits(cfg: ArchConfig, params, tokens) -> int:
    """Bits the device would transmit per request (last-token remote
    channels, 3-bit codebook) — before LZW."""
    feats = extract_token_features(params, tokens)
    _, f_remote = split_features(feats, cfg.agile.k)
    return int(f_remote[:, -1].size) * 3
