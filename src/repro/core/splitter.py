"""Runtime feature split (paper §3, Figure 5).

At inference the XAI tool is unavailable; the disorder loss guarantees the
top-k important features sit in the FIRST k channels, so the split is a
zero-cost slice — this is precisely the computation the paper migrates
from online inference to offline training.
"""
from __future__ import annotations

import jax.numpy as jnp


def split_features(feats: jnp.ndarray, k: int):
    """feats: (B, ..., C) -> (local (B, ..., k), remote (B, ..., C-k))."""
    return feats[..., :k], feats[..., k:]


def merge_features(local: jnp.ndarray, remote: jnp.ndarray) -> jnp.ndarray:
    return jnp.concatenate([local, remote], axis=-1)


def apply_channel_permutation(feats: jnp.ndarray, perm: jnp.ndarray) -> jnp.ndarray:
    """Reorder channels (training-time mapping layer; see core.mapping)."""
    return jnp.take(feats, perm, axis=-1)
