"""Skewness-manipulation losses (paper Eq. 1, Eq. 2, §4) and metrics."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def disorder_loss(importance: jnp.ndarray, k: int) -> jnp.ndarray:
    """Eq. (1): max(0, max(I2) - min(I1)) averaged over the batch.

    importance: (B, C) normalized channel importances; the first k channels
    are the designated local (top-k) slots.  Non-zero iff any non-local
    channel out-ranks a local one.
    """
    i1 = importance[:, :k]
    i2 = importance[:, k:]
    viol = jnp.maximum(0.0, jnp.max(i2, axis=-1) - jnp.min(i1, axis=-1))
    return jnp.mean(viol)


def skewness_loss(importance: jnp.ndarray, k: int, rho: float) -> jnp.ndarray:
    """Eq. (2): max(0, rho - |I1|_1) averaged over the batch."""
    i1_mass = jnp.sum(importance[:, :k], axis=-1)
    return jnp.mean(jnp.maximum(0.0, rho - i1_mass))


def descent_loss(importance: jnp.ndarray) -> jnp.ndarray:
    """The strawman §4.1 L_descent = ||I - sort(I, desc)||^2 (used by the
    ablation benchmark to reproduce Figure 9's accuracy drop).

    Implemented via lax.top_k over all C channels (= full descending
    sort): sort/argsort VJPs hit a jax-internal gather issue in this
    environment, while top_k differentiates cleanly."""
    C = importance.shape[-1]
    i_sorted, _ = jax.lax.top_k(importance, C)
    return jnp.mean(jnp.sum((importance - i_sorted) ** 2, axis=-1))


def combined_loss(prediction_loss, importance, *, k: int, rho: float,
                  lam: float, ordering: str = "disorder"):
    """§4.2: L = lam * L_pred + (1 - lam) * (L_skew + L_disorder).

    ordering="descent" swaps in the strawman L_descent (full sort) for the
    Figure-9 ablation.  Returns (total, metrics dict).
    """
    if ordering == "descent":
        l_dis = descent_loss(importance)
    else:
        l_dis = disorder_loss(importance, k)
    l_skew = skewness_loss(importance, k, rho)
    total = lam * prediction_loss + (1.0 - lam) * (l_skew + l_dis)
    return total, {
        "loss_prediction": prediction_loss,
        "loss_disorder": l_dis,
        "loss_skewness": l_skew,
    }


# --------------------------------------------------------------- metrics ---
def topk_mass(importance: jnp.ndarray, k: int) -> jnp.ndarray:
    """Per-sample cumulative normalized importance of the first k channels."""
    return jnp.sum(importance[:, :k], axis=-1)


def achieved_skewness(importance: jnp.ndarray, k: int) -> jnp.ndarray:
    """Batch-mean top-k mass (compare against the rho requirement)."""
    return jnp.mean(topk_mass(importance, k))


def disorder_rate(importance: jnp.ndarray, k: int) -> jnp.ndarray:
    """Fraction of samples where some non-local channel out-ranks a local
    one (the paper's '% disorder cases', target < 2%)."""
    viol = jnp.max(importance[:, k:], axis=-1) > jnp.min(importance[:, :k], axis=-1)
    return jnp.mean(viol.astype(jnp.float32))


def natural_skewness(importance: jnp.ndarray, frac: float = 0.2) -> jnp.ndarray:
    """§2.3 metric: normalized importance mass of the top-`frac` channels
    (by rank, not by position) per sample."""
    C = importance.shape[-1]
    k = max(1, int(round(frac * C)))
    topv = jnp.sort(importance, axis=-1)[:, ::-1][:, :k]
    return jnp.sum(topv, axis=-1)
