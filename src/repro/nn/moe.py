"""Mixture-of-Experts layer (top-k routing, grouped capacity dispatch).

GShard-style grouped dispatch: tokens are reshaped into (G, S) groups of
S <= group_size tokens; routing, position-in-expert cumsums and the
one-hot dispatch/combine tensors are all per-group, so the dispatch
tensor is (G, S, E, C) with C = capacity_factor * S * top_k / E — memory
O(N * S * k * cf) instead of the O(N^2 * k / E) a flat formulation costs
at prefill scale.

Expert-parallel friendly: expert weights carry a leading (n_experts,)
axis sharded over the "model" mesh axis; with tokens (groups) sharded
over "data", the dispatch/combine einsums lower to all-to-all style
collectives under GSPMD.

Capacity semantics: tokens over a group's per-expert capacity are dropped
(they fall through the residual connection) — standard Switch/GShard
training behaviour.  Inference paths pass a large capacity_factor
(n_experts / top_k => provably dropless) via the backbone's `dropless`
flag when expert count is small, or 4.0 for very wide expert counts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.nn import init as initializers
from repro.nn.activations import silu
from repro.nn.linear import dense_init
from repro.nn.module import split_keys


def moe_init(key, d_model: int, d_ff: int, n_experts: int, *, dtype=jnp.float32):
    kk = split_keys(key, ["router", "gate", "up", "down"])
    def ek(k, a, b):
        # per-expert stacked weights: (E, a, b)
        return initializers.lecun_normal(k, (n_experts, a, b), dtype, fan_in=a)
    return {
        "router": dense_init(kk["router"], d_model, n_experts, use_bias=False, dtype=dtype),
        "gate": ek(kk["gate"], d_model, d_ff),
        "up": ek(kk["up"], d_model, d_ff),
        "down": ek(kk["down"], d_ff, d_model),
    }


def _expert_ffn(params, x):
    """x: (E, C', d) -> (E, C', d) with per-expert SwiGLU weights."""
    g = silu(jnp.einsum("ecd,edf->ecf", x, params["gate"]))
    u = jnp.einsum("ecd,edf->ecf", x, params["up"])
    return jnp.einsum("ecf,efd->ecd", g * u, params["down"])


def _pick_group_size(N: int, target: int) -> int:
    """Largest power-of-two group size <= target that divides N (falls back
    to N itself for odd token counts)."""
    s = 1
    while s * 2 <= target and N % (s * 2) == 0:
        s *= 2
    return s if N % s == 0 else N


def moe_apply(params, x, *, top_k: int, capacity_factor: float = 1.25,
              min_capacity: int = 4, group_size: int = 4096):
    """x: (B, T, d).  Returns (y, aux) where aux has the load-balance loss."""
    B, T, d = x.shape
    E = params["router"]["w"].shape[1]
    N = B * T
    S = _pick_group_size(N, group_size)
    G = N // S
    xt = x.reshape(G, S, d)

    logits = jnp.einsum("gsd,de->gse", xt.astype(jnp.float32),
                        params["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                      # (G, S, E)

    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)          # (G, S, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)        # renormalize

    capacity = max(min_capacity, int(capacity_factor * S * top_k / E))
    capacity = min(capacity, S)

    # one-hot over experts per routing slot: (K, G, S, E)
    onehot = jax.nn.one_hot(
        jnp.moveaxis(expert_idx, -1, 0), E, dtype=jnp.float32)
    # position of each token within its expert (per group), counting slot-
    # major: slot 0 tokens first, then slot 1, etc.
    oh_km = onehot.transpose(1, 0, 2, 3).reshape(G, top_k * S, E)
    pos = jnp.cumsum(oh_km, axis=1) - oh_km                      # (G, K*S, E)
    pos = jnp.sum(pos * oh_km, axis=-1).reshape(G, top_k, S)     # (G, K, S)
    pos = pos.transpose(1, 0, 2)                                 # (K, G, S)
    keep = pos < capacity

    gates_k = jnp.moveaxis(gate_vals, -1, 0) * keep              # (K, G, S)
    pos_oh = jax.nn.one_hot(pos, capacity, dtype=jnp.float32)    # (K, G, S, C)
    dispatch = jnp.einsum("kgse,kgsc->gsec", onehot * keep[..., None], pos_oh)
    combine = jnp.einsum("kgse,kgsc,kgs->gsec", onehot, pos_oh, gates_k)

    xin = jnp.einsum("gsd,gsec->gecd", xt, dispatch)             # (G, E, C, d)
    # the expert axis placement (the all-to-all boundary) propagates from
    # the expert weight shardings; no explicit constraint so the strategy
    # (1D model-parallel vs 2D resident) stays a pure partition-rule choice
    xe = xin.transpose(1, 0, 2, 3).reshape(E, G * capacity, d).astype(x.dtype)
    yout = _expert_ffn({k: params[k] for k in ("gate", "up", "down")}, xe)
    yout = yout.reshape(E, G, capacity, d).transpose(1, 0, 2, 3)  # (G, E, C, d)
    y = jnp.einsum("gecd,gsec->gsd", yout.astype(jnp.float32), combine)

    # Switch-style load-balance loss (over all tokens)
    density = jnp.mean(onehot[0].reshape(-1, E), axis=0)
    mean_probs = jnp.mean(probs.reshape(-1, E), axis=0)
    lb_loss = E * jnp.sum(density * mean_probs)

    aux = {"load_balance_loss": lb_loss,
           "dropped_fraction": 1.0 - jnp.mean(keep.astype(jnp.float32))}
    return y.reshape(B, T, d).astype(x.dtype), aux


def _in_mesh_context() -> bool:
    """True when called under a concrete mesh context (dry-run/launcher)."""
    try:
        from jax._src.mesh import thread_resources
        return not thread_resources.env.physical_mesh.empty
    except Exception:
        return False


def moe_reference(params, x, *, top_k: int):
    """Oracle: loop over experts, no capacity limit (tests use small E)."""
    B, T, d = x.shape
    E = params["router"]["w"].shape[1]
    xt = x.reshape(-1, d)
    logits = xt.astype(jnp.float32) @ params["router"]["w"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    y = jnp.zeros_like(xt, dtype=jnp.float32)
    for e in range(E):
        pe = {"gate": params["gate"][e], "up": params["up"][e], "down": params["down"][e]}
        fe = (jnp.einsum("nf,fd->nd", silu(xt @ pe["gate"]) * (xt @ pe["up"]), pe["down"]))
        w = jnp.sum(jnp.where(expert_idx == e, gate_vals, 0.0), axis=-1)
        y = y + w[:, None] * fe.astype(jnp.float32)
    return y.reshape(B, T, d).astype(x.dtype)
