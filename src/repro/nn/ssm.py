"""Mamba-style selective state-space block.

Training/prefill path: chunked selective scan -- an outer lax.scan over
sequence chunks carrying the (B, d_inner, d_state) hidden state, with an
associative scan inside each chunk.  This bounds temporary memory to
O(chunk * d_inner * d_state) instead of O(T * d_inner * d_state), which is
what makes the jamba-scale configs lowerable.

Decode path: O(1) per token -- carries (conv_state, ssm_state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import init as initializers
from repro.nn.activations import silu
from repro.nn.linear import conv1d_apply, dense_apply, dense_init
from repro.nn.module import split_keys


def mamba_init(key, d_model: int, *, expand: int = 2, d_state: int = 16,
               d_conv: int = 4, dt_rank: int | None = None, dtype=jnp.float32):
    d_inner = expand * d_model
    dt_rank = dt_rank or max(1, d_model // 16)
    kk = split_keys(key, ["in_proj", "conv", "x_proj", "dt_proj", "out_proj", "dt_bias"])
    # conv kernel: depthwise (d_conv, 1, d_inner) via feature_group_count
    conv_w = initializers.he_normal(kk["conv"], (d_conv, 1, d_inner), dtype, fan_in=d_conv)
    # S4D-real initialization for A
    A = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32)[None, :], (d_inner, 1))
    dt = jax.random.uniform(kk["dt_bias"], (d_inner,), jnp.float32,
                            minval=0.001, maxval=0.1)
    dt_bias = jnp.log(jnp.expm1(dt))  # inverse softplus
    return {
        "in_proj": dense_init(kk["in_proj"], d_model, 2 * d_inner, use_bias=False, dtype=dtype),
        "conv_w": conv_w,
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": dense_init(kk["x_proj"], d_inner, dt_rank + 2 * d_state, use_bias=False, dtype=dtype),
        "dt_proj": dense_init(kk["dt_proj"], dt_rank, d_inner, use_bias=False, dtype=dtype),
        "dt_bias": dt_bias,
        "A_log": jnp.log(A),
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(kk["out_proj"], d_inner, d_model, use_bias=False, dtype=dtype),
    }


def _ssm_params(params, x_in, *, d_state: int, dt_rank: int):
    """Per-token SSM parameters from the post-conv activations.

    x_in: (B, T, d_inner) -> dt (B,T,d_inner), B_mat/C_mat (B,T,d_state)
    """
    proj = dense_apply(params["x_proj"], x_in)
    dt_low, B_mat, C_mat = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(dense_apply(params["dt_proj"], dt_low).astype(jnp.float32)
                         + params["dt_bias"])
    return dt, B_mat.astype(jnp.float32), C_mat.astype(jnp.float32)


def _chunk_scan(h0, decay, inp):
    """Associative scan within a chunk.

    h_t = decay_t * h_{t-1} + inp_t, over axis 0 (time).
    decay/inp: (Tc, B, d_inner, d_state); h0: (B, d_inner, d_state).
    Returns all h (Tc, ...) and the final state.
    """
    # fold h0 into the first input
    inp = inp.at[0].add(decay[0] * h0)

    def combine(a, b):
        da, xa = a
        db, xb = b
        return da * db, db * xa + xb

    ds, hs = jax.lax.associative_scan(combine, (decay, inp), axis=0)
    return hs, hs[-1]


def mamba_scan(dt, A, B_mat, C_mat, x, h0, *, chunk: int = 128):
    """Chunked selective scan.

    dt, x: (B, T, d_inner); A: (d_inner, d_state);
    B_mat, C_mat: (B, T, d_state); h0: (B, d_inner, d_state).
    Returns y (B, T, d_inner) float32 and final state.
    """
    Bsz, T, d_inner = x.shape
    d_state = A.shape[1]
    Tc = min(chunk, T)
    n_chunks = -(-T // Tc)
    Tp = n_chunks * Tc
    pad = Tp - T

    def padt(a):
        return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))

    dt_p, x_p, B_p, C_p = padt(dt), padt(x.astype(jnp.float32)), padt(B_mat), padt(C_mat)
    # decay_t = exp(dt_t * A) ; inp_t = dt_t * B_t * x_t
    # shapes: (B, T, d_inner, d_state)
    def chunk_body(h, idx):
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, idx * Tc, Tc, axis=1)
        dt_c, x_c, B_c, C_c = sl(dt_p), sl(x_p), sl(B_p), sl(C_p)
        dA = jnp.exp(dt_c[..., None] * (-jnp.exp(A))[None, None])   # (B,Tc,di,ds)
        dBx = (dt_c * x_c)[..., None] * B_c[:, :, None, :]          # (B,Tc,di,ds)
        hs, h_last = _chunk_scan(h, dA.transpose(1, 0, 2, 3), dBx.transpose(1, 0, 2, 3))
        y_c = jnp.einsum("tbds,bts->btd", hs, C_c)
        return h_last, y_c

    h_final, ys = jax.lax.scan(chunk_body, h0, jnp.arange(n_chunks))
    y = ys.transpose(1, 0, 2, 3).reshape(Bsz, Tp, d_inner)   # (B, Tp, d_inner)
    return y[:, :T], h_final


def mamba_apply(params, x, *, d_state: int = 16, d_conv: int = 4,
                dt_rank: int | None = None, chunk: int = 128,
                return_state: bool = False):
    """Full block for train/prefill.  x: (B, T, d_model).

    With return_state, also returns the decode state ({conv, ssm}) after
    the last token, for prefill -> decode handoff.
    """
    B, T, d_model = x.shape
    d_inner = params["conv_b"].shape[0]
    dt_rank = dt_rank or max(1, d_model // 16)
    xz = dense_apply(params["in_proj"], x)
    x_in, z = jnp.split(xz, 2, axis=-1)
    # causal depthwise conv
    x_pad = jnp.pad(x_in, ((0, 0), (d_conv - 1, 0), (0, 0)))
    x_c = conv1d_apply({"w": params["conv_w"], "b": params["conv_b"]}, x_pad,
                       padding="VALID", feature_group_count=d_inner)
    x_c = silu(x_c)
    dt, B_mat, C_mat = _ssm_params(params, x_c, d_state=d_state, dt_rank=dt_rank)
    h0 = jnp.zeros((B, d_inner, d_state), jnp.float32)
    y, h_final = mamba_scan(dt, params["A_log"], B_mat, C_mat, x_c, h0, chunk=chunk)
    y = y + params["D"] * x_c.astype(jnp.float32)
    y = y.astype(x.dtype) * silu(z)
    out = dense_apply(params["out_proj"], y)
    if return_state:
        state = {"conv": x_pad[:, T:, :], "ssm": h_final}
        return out, state
    return out


def mamba_decode_init_state(batch: int, d_inner: int, d_state: int, d_conv: int,
                            dtype=jnp.float32):
    return {
        "conv": jnp.zeros((batch, d_conv - 1, d_inner), dtype),
        "ssm": jnp.zeros((batch, d_inner, d_state), jnp.float32),
    }


def mamba_decode_apply(params, x, state, *, d_state: int = 16, d_conv: int = 4,
                       dt_rank: int | None = None):
    """One token.  x: (B, 1, d_model).  Returns (y, new_state)."""
    B, _, d_model = x.shape
    d_inner = params["conv_b"].shape[0]
    dt_rank = dt_rank or max(1, d_model // 16)
    xz = dense_apply(params["in_proj"], x)
    x_in, z = jnp.split(xz, 2, axis=-1)          # (B, 1, d_inner)
    conv_buf = jnp.concatenate([state["conv"], x_in], axis=1)  # (B, d_conv, d_inner)
    x_c = jnp.einsum("bkd,kd->bd", conv_buf,
                     params["conv_w"][:, 0, :]) + params["conv_b"]
    x_c = silu(x_c)[:, None, :]                   # (B, 1, d_inner)
    dt, B_mat, C_mat = _ssm_params(params, x_c, d_state=d_state, dt_rank=dt_rank)
    dA = jnp.exp(dt[:, 0, :, None] * (-jnp.exp(params["A_log"]))[None])
    dBx = (dt[:, 0] * x_c[:, 0].astype(jnp.float32))[..., None] * B_mat[:, 0, None, :]
    h = dA * state["ssm"] + dBx
    y = jnp.einsum("bds,bs->bd", h, C_mat[:, 0])
    y = y + params["D"] * x_c[:, 0].astype(jnp.float32)
    y = (y[:, None, :].astype(x.dtype)) * silu(z)
    out = dense_apply(params["out_proj"], y)
    return out, {"conv": conv_buf[:, 1:], "ssm": h}


def mamba_reference(params, x, *, d_state: int = 16, d_conv: int = 4,
                    dt_rank: int | None = None):
    """Sequential-oracle full block (tests): step decode over T."""
    B, T, _ = x.shape
    d_inner = params["conv_b"].shape[0]
    state = mamba_decode_init_state(B, d_inner, d_state, d_conv, dtype=x.dtype)
    ys = []
    for t in range(T):
        y, state = mamba_decode_apply(params, x[:, t:t + 1], state,
                                      d_state=d_state, d_conv=d_conv, dt_rank=dt_rank)
        ys.append(y)
    return jnp.concatenate(ys, axis=1)
