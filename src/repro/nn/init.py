"""Weight initializers (pure functions of a PRNG key)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def lecun_normal(key, shape, dtype=jnp.float32, fan_in: int | None = None):
    fan = fan_in if fan_in is not None else shape[0]
    std = 1.0 / math.sqrt(max(1, fan))
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def he_normal(key, shape, dtype=jnp.float32, fan_in: int | None = None):
    fan = fan_in if fan_in is not None else shape[0]
    std = math.sqrt(2.0 / max(1, fan))
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def normal(key, shape, dtype=jnp.float32, std: float = 0.02):
    return std * jax.random.normal(key, shape, dtype)


def zeros(key, shape, dtype=jnp.float32):
    del key
    return jnp.zeros(shape, dtype)


def ones(key, shape, dtype=jnp.float32):
    del key
    return jnp.ones(shape, dtype)


def conv_kernel_fan_in(kernel_shape) -> int:
    """Fan-in for an HWIO conv kernel (kh, kw, cin, cout)."""
    kh, kw, cin, _ = kernel_shape
    return kh * kw * cin
