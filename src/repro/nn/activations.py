"""Activation functions and gated FFNs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.linear import dense_apply, dense_init
from repro.nn.module import split_keys


def silu(x):
    return x * jax.nn.sigmoid(x)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


# SwiGLU feed-forward (llama/qwen/mixtral style)
def swiglu_ffn_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    kk = split_keys(key, ["gate", "up", "down"])
    return {
        "gate": dense_init(kk["gate"], d_model, d_ff, use_bias=False, dtype=dtype),
        "up": dense_init(kk["up"], d_model, d_ff, use_bias=False, dtype=dtype),
        "down": dense_init(kk["down"], d_ff, d_model, use_bias=False, dtype=dtype),
    }


def swiglu_ffn_apply(params, x):
    g = silu(dense_apply(params["gate"], x))
    u = dense_apply(params["up"], x)
    return dense_apply(params["down"], g * u)


# Plain MLP (whisper/vit style)
def mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    kk = split_keys(key, ["fc1", "fc2"])
    return {
        "fc1": dense_init(kk["fc1"], d_model, d_ff, use_bias=True, dtype=dtype),
        "fc2": dense_init(kk["fc2"], d_ff, d_model, use_bias=True, dtype=dtype),
    }


def mlp_apply(params, x):
    return dense_apply(params["fc2"], gelu(dense_apply(params["fc1"], x)))
