"""Attention: GQA / MHA, causal, sliding-window, flash-style blocked softmax.

Layout conventions:
  queries      (B, T, Hq, D)
  keys/values  (B, S, Hkv, D)     Hq % Hkv == 0 (GQA groups)

`flash_attention` is the training/prefill path: a lax.scan over KV blocks
(and an outer scan over query chunks) with an online-softmax accumulator,
so the (T, S) score matrix is never materialized.  `decode_attention` is
the single-token serving path.  Both support causal masking and a
sliding window (window > 0 => only the last `window` positions attend).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.ops import paged_decode_attention
from repro.nn import init as initializers
from repro.nn.linear import dense_apply, dense_init
from repro.nn.module import split_keys
from repro.nn.rope import apply_rope

NEG_INF = -1e30


# ------------------------------------------------------------ projections --
def attention_init(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int | None = None, *, qkv_bias: bool = False,
                   dtype=jnp.float32):
    head_dim = head_dim or d_model // n_heads
    kk = split_keys(key, ["wq", "wk", "wv", "wo"])
    return {
        "wq": dense_init(kk["wq"], d_model, n_heads * head_dim, use_bias=qkv_bias, dtype=dtype),
        "wk": dense_init(kk["wk"], d_model, n_kv_heads * head_dim, use_bias=qkv_bias, dtype=dtype),
        "wv": dense_init(kk["wv"], d_model, n_kv_heads * head_dim, use_bias=qkv_bias, dtype=dtype),
        "wo": dense_init(kk["wo"], n_heads * head_dim, d_model, use_bias=False, dtype=dtype),
    }


def project_qkv(params, x, *, n_heads: int, n_kv_heads: int, head_dim: int):
    B, T, _ = x.shape
    q = dense_apply(params["wq"], x).reshape(B, T, n_heads, head_dim)
    k = dense_apply(params["wk"], x).reshape(B, T, n_kv_heads, head_dim)
    v = dense_apply(params["wv"], x).reshape(B, T, n_kv_heads, head_dim)
    return q, k, v


# ------------------------------------------------------------ flash core ---
def _block_attend(q, k, v, q_pos, k_pos, *, causal: bool, window: int,
                  scale: float, m_prev, l_prev, acc_prev, kv_valid_len=None):
    """One online-softmax update for a (q_chunk, kv_block) tile.

    q: (B, Tq, Hkv, G, D);  k/v: (B, Sk, Hkv, D)
    m/l: (B, Hkv, G, Tq);   acc: (B, Tq, Hkv, G, D)
    kv_valid_len: optional (B,) per-row count of valid key positions
    (right-padded prefill batches mask pad keys out of every row).
    """
    s = jnp.einsum("bthgd,bshd->bhgts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale           # (B,Hkv,G,Tq,Sk)
    mask = jnp.ones(s.shape[-2:], dtype=bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    if kv_valid_len is not None:
        mask = mask[None] & (k_pos[None, None, :]
                             < kv_valid_len[:, None, None])  # (B,Tq,Sk)
        maskx = mask[:, None, None]                          # vs (B,Hkv,G,Tq,Sk)
    else:
        maskx = mask
    s = jnp.where(maskx, s, NEG_INF)

    m_cur = jnp.max(s, axis=-1)                             # (B,Hkv,G,Tq)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows: keep m finite
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(maskx, p, 0.0)
    corr = jnp.exp(jnp.where(m_prev <= NEG_INF / 2, NEG_INF, m_prev) - m_safe)
    corr = jnp.where(m_prev <= NEG_INF / 2, 0.0, corr)
    l_new = corr * l_prev + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bhgts,bshd->bthgd", p, v.astype(jnp.float32))
    acc_new = acc_prev * corr.transpose(0, 3, 1, 2)[..., None] + pv
    return m_new, l_new, acc_new


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_block: int = 512, kv_block: int = 1024,
                    q_offset: int = 0, kv_valid_len=None) -> jnp.ndarray:
    """Blocked attention; never materializes (T, S).

    q: (B, T, Hq, D), k/v: (B, S, Hkv, D).  q_offset: absolute position of
    q[0] relative to k[0] (for chunked prefill continuation).
    kv_valid_len: optional (B,) count of valid keys per row — keys at or
    beyond it never receive probability mass (bucketed prefill padding).
    Returns (B, T, Hq, D) in q.dtype.
    """
    B, T, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)

    qb = min(q_block, T)
    kb = min(kv_block, S)
    # pad to multiples
    Tp = -(-T // qb) * qb
    Sp = -(-S // kb) * kb
    qp = jnp.pad(q, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    q_positions = jnp.arange(Tp) + q_offset
    k_positions = jnp.where(jnp.arange(Sp) < S, jnp.arange(Sp), 2**30)  # pad keys out of window

    qg = qp.reshape(B, Tp // qb, qb, Hkv, G, D)
    kg = kp.reshape(B, Sp // kb, kb, Hkv, D)
    vg = vp.reshape(B, Sp // kb, kb, Hkv, D)
    qpos_g = q_positions.reshape(Tp // qb, qb)
    kpos_g = k_positions.reshape(Sp // kb, kb)

    def per_q_chunk(q_chunk, q_pos):
        # q_chunk: (B, qb, Hkv, G, D)
        m0 = jnp.full((B, Hkv, G, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qb), jnp.float32)
        a0 = jnp.zeros((B, qb, Hkv, G, D), jnp.float32)

        def body(carry, kv):
            m, l, a = carry
            k_blk, v_blk, k_pos = kv
            m, l, a = _block_attend(q_chunk, k_blk, v_blk, q_pos, k_pos,
                                    causal=causal, window=window, scale=scale,
                                    m_prev=m, l_prev=l, acc_prev=a,
                                    kv_valid_len=kv_valid_len)
            return (m, l, a), None

        (m, l, a), _ = jax.lax.scan(
            body, (m0, l0, a0),
            (kg.swapaxes(0, 1), vg.swapaxes(0, 1), kpos_g))
        l = jnp.maximum(l, 1e-20)
        out = a / l.transpose(0, 3, 1, 2)[..., None]
        return out  # (B, qb, Hkv, G, D)

    def q_body(_, qc):
        q_chunk, q_pos = qc
        return None, per_q_chunk(q_chunk, q_pos)

    _, outs = jax.lax.scan(q_body, None, (qg.swapaxes(0, 1), qpos_g))
    # outs: (nq, B, qb, Hkv, G, D)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Tp, Hq, D)
    return out[:, :T].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, attend_len) -> jnp.ndarray:
    """Single-step attention against a cache.

    q: (B, 1, Hq, D); k/v_cache: (B, S, Hkv, D); attend_len: () or (B,)
    number of valid cache slots (per-row counts serve slot pools whose
    rows sit at different depths).  Ring buffers (SWA) pass attend_len ==
    S once full; slot order does not matter because keys carry absolute
    RoPE phases.  Returns (B, 1, Hq, D).

    Caches whose width splits into KV pages route through the paged
    subsystem (`repro.kernels.decode_attention`): only the pages below
    max(attend_len) are visited, and the fallback path is bit-identical
    to the dense einsum this function used to inline.
    """
    return paged_decode_attention(q, k_cache, v_cache, attend_len)


# ----------------------------------------------------------- full layer ----
def attention_apply(params, x, *, n_heads: int, n_kv_heads: int,
                    head_dim: int, causal: bool = True, window: int = 0,
                    rope_theta: float = 10000.0, positions=None,
                    q_block: int = 512, kv_block: int = 1024,
                    return_kv: bool = False, kv_valid_len=None):
    """Self-attention over x: (B, T, d_model).

    With return_kv, also returns the (roped) K/V tensors (B, T, Hkv, D)
    so prefill can populate a decode cache.  kv_valid_len (B,) masks
    right-padding keys out of every row (bucketed prefill).
    """
    B, T, _ = x.shape
    q, k, v = project_qkv(params, x, n_heads=n_heads, n_kv_heads=n_kv_heads,
                          head_dim=head_dim)
    if positions is None:
        positions = jnp.arange(T)[None, :]
    if rope_theta > 0:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_block=q_block, kv_block=kv_block,
                          kv_valid_len=kv_valid_len)
    out = out.reshape(B, T, n_heads * head_dim)
    y = dense_apply(params["wo"], out)
    if return_kv:
        return y, k, v
    return y


def cross_attention_apply(params, x, k, v, *, n_heads: int, head_dim: int):
    """Encoder-decoder cross attention; k/v precomputed (B, F, H, D)."""
    B, T, _ = x.shape
    q = dense_apply(params["wq"], x).reshape(B, T, n_heads, head_dim)
    out = flash_attention(q, k, v, causal=False)
    out = out.reshape(B, T, n_heads * head_dim)
    return dense_apply(params["wo"], out)


def cross_kv(params, enc_out, *, n_kv_heads: int, head_dim: int):
    """Precompute cross-attention K/V from encoder output."""
    B, F, _ = enc_out.shape
    k = dense_apply(params["wk"], enc_out).reshape(B, F, n_kv_heads, head_dim)
    v = dense_apply(params["wv"], enc_out).reshape(B, F, n_kv_heads, head_dim)
    return k, v


def cross_attention_decode(params, x, k, v, *, n_heads: int, head_dim: int):
    """One-token cross attention (cache = precomputed encoder K/V)."""
    B = x.shape[0]
    q = dense_apply(params["wq"], x).reshape(B, 1, n_heads, head_dim)
    out = decode_attention(q, k, v, attend_len=k.shape[1])
    out = out.reshape(B, 1, n_heads * head_dim)
    return dense_apply(params["wo"], out)


def attention_decode_apply(params, x, k_cache, v_cache, cache_len, *,
                           n_heads: int, n_kv_heads: int, head_dim: int,
                           rope_theta: float = 10000.0):
    """One-token decode.  x: (B, 1, d_model); cache_len: () or (B,) tokens
    seen so far (per-row counts let a slot pool decode rows that sit at
    different context depths in one program).

    The cache is a ring buffer of size S (SWA archs size it to the window;
    full-attention archs size it to the max context).  The new token's K/V
    are written at cache_len % S; attention covers min(cache_len + 1, S)
    slots.  Returns (out (B,1,d_model), new_k_cache, new_v_cache).
    """
    B = x.shape[0]
    S = k_cache.shape[1]
    q, k, v = project_qkv(params, x, n_heads=n_heads, n_kv_heads=n_kv_heads,
                          head_dim=head_dim)
    pos = jnp.asarray(cache_len)
    pos_b = jnp.broadcast_to(pos, (B,))[:, None]
    if rope_theta > 0:
        q = apply_rope(q, pos_b, rope_theta)
        k = apply_rope(k, pos_b, rope_theta)
    if pos.ndim == 0:
        idx = pos % S
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), idx, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), idx, axis=1)
    else:
        idx = pos_b[:, 0] % S                  # per-row write slot
        k_cache = k_cache.at[jnp.arange(B), idx].set(k[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[jnp.arange(B), idx].set(v[:, 0].astype(v_cache.dtype))
    attend_len = jnp.minimum(pos + 1, S)
    out = decode_attention(q, k_cache, v_cache, attend_len)
    out = out.reshape(B, 1, n_heads * head_dim)
    return dense_apply(params["wo"], out), k_cache, v_cache


# ----------------------------------------------------------- references ----
def reference_attention(q, k, v, *, causal: bool = True, window: int = 0,
                        q_offset: int = 0) -> jnp.ndarray:
    """O(T*S)-memory oracle used by tests."""
    B, T, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, T, Hkv, G, D)
    s = jnp.einsum("bthgd,bshd->bhgts", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(T) + q_offset
    k_pos = jnp.arange(S)
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask, p, 0.0)
    out = jnp.einsum("bhgts,bshd->bthgd", p, v.astype(jnp.float32))
    return out.reshape(B, T, Hq, D).astype(q.dtype)
