"""Normalization layers."""
from __future__ import annotations

import jax.numpy as jnp


def rmsnorm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm_apply(params, x, *, eps: float = 1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * (var + eps) ** -0.5
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def layernorm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm_apply(params, x, *, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * (var + eps) ** -0.5
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dtype)


def groupnorm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def groupnorm_apply(params, x, *, groups: int, eps: float = 1e-5):
    """GroupNorm over the channel dim (last axis)."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    shape = x32.shape
    g = groups
    xg = x32.reshape(shape[:-1] + (g, shape[-1] // g))
    mu = jnp.mean(xg, axis=-1, keepdims=True)
    var = jnp.var(xg, axis=-1, keepdims=True)
    yg = (xg - mu) * (var + eps) ** -0.5
    y = yg.reshape(shape)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dtype)
