"""Dense / conv / embedding primitives."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import init as initializers
from repro.nn.module import split_keys


# ---------------------------------------------------------------- dense ----
def dense_init(key, in_dim: int, out_dim: int, *, use_bias: bool = True,
               dtype=jnp.float32, std: float | None = None):
    kk = split_keys(key, ["w", "b"])
    if std is None:
        w = initializers.lecun_normal(kk["w"], (in_dim, out_dim), dtype, fan_in=in_dim)
    else:
        w = initializers.normal(kk["w"], (in_dim, out_dim), dtype, std=std)
    p = {"w": w}
    if use_bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def dense_apply(params, x):
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y


# ----------------------------------------------------------------- conv ----
def conv2d_init(key, in_ch: int, out_ch: int, kernel: int = 3, *,
                use_bias: bool = True, dtype=jnp.float32):
    kk = split_keys(key, ["w", "b"])
    shape = (kernel, kernel, in_ch, out_ch)  # HWIO
    w = initializers.he_normal(kk["w"], shape, dtype,
                               fan_in=initializers.conv_kernel_fan_in(shape))
    p = {"w": w}
    if use_bias:
        p["b"] = jnp.zeros((out_ch,), dtype)
    return p


def conv2d_apply(params, x, *, stride: int = 1, padding: str = "SAME"):
    """x: (B, H, W, C) NHWC."""
    y = jax.lax.conv_general_dilated(
        x, params["w"], window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if "b" in params:
        y = y + params["b"]
    return y


def conv1d_init(key, in_ch: int, out_ch: int, kernel: int, *,
                use_bias: bool = True, dtype=jnp.float32):
    kk = split_keys(key, ["w", "b"])
    shape = (kernel, in_ch, out_ch)  # WIO
    fan = kernel * in_ch
    w = initializers.he_normal(kk["w"], shape, dtype, fan_in=fan)
    p = {"w": w}
    if use_bias:
        p["b"] = jnp.zeros((out_ch,), dtype)
    return p


def conv1d_apply(params, x, *, stride: int = 1, padding: str = "SAME",
                 feature_group_count: int = 1):
    """x: (B, T, C)."""
    y = jax.lax.conv_general_dilated(
        x, params["w"], window_strides=(stride,), padding=padding,
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=feature_group_count)
    if "b" in params:
        y = y + params["b"]
    return y


# ------------------------------------------------------------ embedding ----
def embedding_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return {"table": initializers.normal(key, (vocab, dim), dtype, std=0.02)}


def embedding_apply(params, ids):
    return jnp.take(params["table"], ids, axis=0)


def embedding_attend(params, x):
    """Tied-readout logits: x @ table.T."""
    return x @ params["table"].T
