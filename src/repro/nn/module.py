"""Minimal functional parameter utilities.

The whole framework is purely functional: parameters are nested dicts
(pytrees) of jnp arrays.  Every layer exposes

    init(key, ...) -> params        (a pytree)
    apply(params, x, ...) -> y

Helpers here cover RNG splitting, parameter counting, pytree paths and
dtype casting.  No stateful module system -- state (KV caches, SSM
states, optimizer moments) is always threaded explicitly.
"""
from __future__ import annotations

from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp

Params = Any  # nested dict pytree of arrays


def split_keys(key: jax.Array, names: list[str]) -> dict[str, jax.Array]:
    """Split one PRNG key into a dict of named keys (order-stable)."""
    keys = jax.random.split(key, len(names))
    return {n: k for n, k in zip(names, keys)}


def param_count(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


def param_bytes(params: Params) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(params))


def tree_paths(params: Params) -> Iterator[tuple[str, jax.Array]]:
    """Yield ('a/b/c', leaf) for every leaf."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    for path, leaf in flat:
        name = "/".join(_key_str(k) for k in path)
        yield name, leaf


def _key_str(k) -> str:
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    if isinstance(k, jax.tree_util.SequenceKey):
        return str(k.idx)
    if isinstance(k, jax.tree_util.GetAttrKey):
        return str(k.name)
    return str(k)


def map_with_path(fn: Callable[[str, jax.Array], Any], params: Params) -> Params:
    """tree_map where fn also receives the 'a/b/c' path string."""

    def _fn(path, leaf):
        name = "/".join(_key_str(k) for k in path)
        return fn(name, leaf)

    return jax.tree_util.tree_map_with_path(_fn, params)


def cast_floats(params: Params, dtype) -> Params:
    def _cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree_util.tree_map(_cast, params)
