"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM training path uses a chunkwise-parallel form (lightning-attention
style): within-chunk quadratic attention with exponential-gate decay
masks + a cross-chunk recurrent matrix state C (B, H, D, D) carried by a
lax.scan.  This is the TPU-native adaptation: MXU-friendly within-chunk
matmuls, O(T/chunk) sequential steps.

Gating follows the xLSTM stabilization: log-space forget-gate cumsums and
a running max-stabilizer m, with the normalizer n lower-bounded by
exp(-m) (|n^T q| vs 1 in the paper's notation).

sLSTM is inherently sequential (state mixing) and uses a plain lax.scan.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.nn import init as initializers
from repro.nn.linear import dense_apply, dense_init
from repro.nn.module import split_keys
from repro.nn.norm import groupnorm_apply, groupnorm_init


# ================================================================= mLSTM ===
def mlstm_init(key, d_model: int, n_heads: int, dtype=jnp.float32):
    head_dim = d_model // n_heads
    kk = split_keys(key, ["wq", "wk", "wv", "wi", "wf", "wo", "out", "norm"])
    p = {
        "wq": dense_init(kk["wq"], d_model, d_model, use_bias=False, dtype=dtype),
        "wk": dense_init(kk["wk"], d_model, d_model, use_bias=False, dtype=dtype),
        "wv": dense_init(kk["wv"], d_model, d_model, use_bias=False, dtype=dtype),
        "wi": dense_init(kk["wi"], d_model, n_heads, use_bias=True, dtype=dtype),
        "wf": dense_init(kk["wf"], d_model, n_heads, use_bias=True, dtype=dtype),
        "out": dense_init(kk["out"], d_model, d_model, use_bias=False, dtype=dtype),
        "norm": groupnorm_init(d_model, dtype),
    }
    # bias forget gate towards remembering
    p["wf"]["b"] = p["wf"]["b"] + 3.0
    return p


def mlstm_sequential(q, k, v, log_i, log_f):
    """Oracle: step-by-step mLSTM.  q,k,v: (B,T,H,D); gates: (B,T,H) logspace.

    Returns (B, T, H, D) float32.
    """
    B, T, H, D = q.shape
    C = jnp.zeros((B, H, D, D), jnp.float32)
    n = jnp.zeros((B, H, D), jnp.float32)
    m = jnp.full((B, H), -jnp.inf, jnp.float32)
    ys = []
    for t in range(T):
        qt, kt, vt = q[:, t].astype(jnp.float32), k[:, t].astype(jnp.float32), v[:, t].astype(jnp.float32)
        lf, li = log_f[:, t], log_i[:, t]
        m_new = jnp.maximum(lf + m, li)
        fg = jnp.exp(lf + m - m_new)
        ig = jnp.exp(li - m_new)
        C = fg[..., None, None] * C + ig[..., None, None] * (kt[..., :, None] * vt[..., None, :])
        n = fg[..., None] * n + ig[..., None] * kt
        m = m_new
        num = jnp.einsum("bhd,bhde->bhe", qt, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n)), jnp.exp(-m))
        ys.append(num / den[..., None])
    return jnp.stack(ys, axis=1)


def mlstm_chunked(q, k, v, log_i, log_f, *, chunk: int = 64):
    """Chunkwise-parallel mLSTM, matches mlstm_sequential.

    q,k,v: (B,T,H,D) (q pre-scaled by caller); log_i/log_f: (B,T,H).
    """
    B, T, H, D = q.shape
    Tc = min(chunk, T)
    n_chunks = -(-T // Tc)
    Tp = n_chunks * Tc
    pad = Tp - T

    def padt(a, fill=0.0):
        return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2),
                       constant_values=fill)

    qf = padt(q.astype(jnp.float32))
    kf = padt(k.astype(jnp.float32))
    vf = padt(v.astype(jnp.float32))
    # padded tail: i gate -> -inf (no contribution), f gate -> 0 (keep state)
    lif = padt(log_i, fill=-1e30)
    lff = padt(log_f, fill=0.0)

    def reshape_c(a):
        return a.reshape((B, n_chunks, Tc) + a.shape[2:]).swapaxes(0, 1)

    qc, kc, vc, lic, lfc = map(reshape_c, (qf, kf, vf, lif, lff))
    # per chunk: (n_chunks, B, Tc, ...)

    def body(carry, xs):
        C, n, m = carry            # (B,H,D,D), (B,H,D), (B,H)
        qt, kt, vt, li, lf = xs    # (B,Tc,H,D) / (B,Tc,H)
        lf_cum = jnp.cumsum(lf, axis=1)                     # inclusive cumsum
        # local decay matrix: d[t,s] = sum_{s<j<=t} lf_j + li_s  (s <= t)
        # log weight of (t, s) pair = lf_cum[t] - lf_cum[s] + li[s]
        a_t = lf_cum                                        # (B,Tc,H)
        b_s = li - lf_cum                                   # (B,Tc,H)
        # within-chunk stabilizer per row t: m_loc[t] = max_s<=t (a_t + b_s)
        b_run = jax.lax.cummax(b_s, axis=1)
        m_loc = a_t + b_run                                 # (B,Tc,H)
        # cross-chunk stabilizer: m_prev carried through decay
        m_inter = m[:, None, :] + a_t                       # (B,Tc,H)
        m_tot = jnp.maximum(m_loc, m_inter)                 # (B,Tc,H)

        # intra-chunk attention
        logw = (a_t[:, :, None, :] + b_s[:, None, :, :])    # (B,t,s,H)
        tri = jnp.tril(jnp.ones((Tc, Tc), bool))
        logw = jnp.where(tri[None, :, :, None], logw, -jnp.inf)
        w = jnp.exp(logw - m_tot[:, :, None, :])            # (B,t,s,H)
        scores = jnp.einsum("bthd,bshd->btsh", qt, kt)
        weighted = scores * w                               # (B,t,s,H)
        num = jnp.einsum("btsh,bshd->bthd", weighted, vt)
        den = jnp.sum(weighted, axis=2)                     # (B,t,H)

        # cross-chunk contribution: decay of previous state to step t
        cross_w = jnp.exp(m_inter - m_tot)                  # (B,Tc,H)
        num = num + cross_w[..., None] * jnp.einsum("bthd,bhde->bthe", qt, C)
        den = den + cross_w * jnp.einsum("bthd,bhd->bth", qt, n)

        y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_tot))[..., None]

        # state update to end of chunk
        a_last = lf_cum[:, -1]                              # (B,H) total decay
        m_new = jnp.maximum(m + a_last, jnp.max(b_s + a_last[:, None], axis=1))
        # contribution of each in-chunk token to final state:
        w_state = jnp.exp(b_s + a_last[:, None] - m_new[:, None])   # (B,Tc,H)
        C_new = jnp.exp(m + a_last - m_new)[:, :, None, None] * C + \
            jnp.einsum("bth,bthd,bthe->bhde", w_state, kt, vt)
        n_new = jnp.exp(m + a_last - m_new)[..., None] * n + \
            jnp.einsum("bth,bthd->bhd", w_state, kt)
        return (C_new, n_new, m_new), y

    C0 = jnp.zeros((B, H, D, D), jnp.float32)
    n0 = jnp.zeros((B, H, D), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    (Cf, nf, mf), ys = jax.lax.scan(body, (C0, n0, m0), (qc, kc, vc, lic, lfc))
    y = ys.swapaxes(0, 1).reshape(B, Tp, H, D)
    return y[:, :T], {"C": Cf, "n": nf, "m": mf}


def mlstm_apply(params, x, *, n_heads: int, chunk: int = 64,
                return_state: bool = False):
    """x: (B, T, d_model)."""
    B, T, d_model = x.shape
    D = d_model // n_heads
    q = dense_apply(params["wq"], x).reshape(B, T, n_heads, D) / math.sqrt(D)
    k = dense_apply(params["wk"], x).reshape(B, T, n_heads, D)
    v = dense_apply(params["wv"], x).reshape(B, T, n_heads, D)
    log_i = dense_apply(params["wi"], x).astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(dense_apply(params["wf"], x).astype(jnp.float32))
    y, state = mlstm_chunked(q, k, v, log_i, log_f, chunk=chunk)
    y = y.reshape(B, T, d_model).astype(x.dtype)
    y = groupnorm_apply(params["norm"], y, groups=n_heads)
    out = dense_apply(params["out"], y)
    if return_state:
        return out, state
    return out


def mlstm_decode_init_state(batch: int, n_heads: int, head_dim: int):
    return {
        "C": jnp.zeros((batch, n_heads, head_dim, head_dim), jnp.float32),
        "n": jnp.zeros((batch, n_heads, head_dim), jnp.float32),
        "m": jnp.full((batch, n_heads), -1e30, jnp.float32),
    }


def mlstm_decode_apply(params, x, state, *, n_heads: int):
    """One token: x (B, 1, d_model) -> (y, new_state)."""
    B, _, d_model = x.shape
    D = d_model // n_heads
    q = dense_apply(params["wq"], x).reshape(B, n_heads, D).astype(jnp.float32) / math.sqrt(D)
    k = dense_apply(params["wk"], x).reshape(B, n_heads, D).astype(jnp.float32)
    v = dense_apply(params["wv"], x).reshape(B, n_heads, D).astype(jnp.float32)
    li = dense_apply(params["wi"], x)[:, 0].astype(jnp.float32)
    lf = jax.nn.log_sigmoid(dense_apply(params["wf"], x)[:, 0].astype(jnp.float32))
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(lf + m, li)
    fg = jnp.exp(lf + m - m_new)
    ig = jnp.exp(li - m_new)
    C = fg[..., None, None] * C + ig[..., None, None] * (k[..., :, None] * v[..., None, :])
    n = fg[..., None] * n + ig[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)), jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(B, 1, d_model).astype(x.dtype)
    y = groupnorm_apply(params["norm"], y, groups=n_heads)
    return dense_apply(params["out"], y), {"C": C, "n": n, "m": m_new}


# ================================================================= sLSTM ===
def slstm_init(key, d_model: int, n_heads: int, dtype=jnp.float32):
    kk = split_keys(key, ["wx", "wr", "norm"])
    # gates: i, f, z, o  -> 4 * d_model
    p = {
        "wx": dense_init(kk["wx"], d_model, 4 * d_model, use_bias=True, dtype=dtype),
        "wr": dense_init(kk["wr"], d_model, 4 * d_model, use_bias=False, dtype=dtype,
                         std=1.0 / math.sqrt(d_model)),
        "norm": groupnorm_init(d_model, dtype),
    }
    return p


def slstm_step(params, xt, state, *, d_model: int):
    """xt: (B, d_model). state: h, c, n, m each (B, d_model)."""
    h, c, n, m = state
    pre = dense_apply(params["wx"], xt) + dense_apply(params["wr"], h)
    zi, zf, zz, zo = jnp.split(pre.astype(jnp.float32), 4, axis=-1)
    log_f = jax.nn.log_sigmoid(zf)
    m_new = jnp.maximum(log_f + m, zi)
    ig = jnp.exp(zi - m_new)
    fg = jnp.exp(log_f + m - m_new)
    c_new = fg * c + ig * jnp.tanh(zz)
    n_new = fg * n + ig
    h_new = jax.nn.sigmoid(zo) * c_new / jnp.maximum(n_new, 1e-6)
    return h_new.astype(xt.dtype), (h_new.astype(xt.dtype), c_new, n_new, m_new)


def slstm_apply(params, x, *, n_heads: int, return_state: bool = False):
    """x: (B, T, d_model) -> (B, T, d_model), sequential scan over T."""
    B, T, d_model = x.shape
    h0 = jnp.zeros((B, d_model), x.dtype)
    c0 = jnp.zeros((B, d_model), jnp.float32)
    n0 = jnp.zeros((B, d_model), jnp.float32)
    m0 = jnp.full((B, d_model), -1e30, jnp.float32)

    def body(state, xt):
        y, new_state = slstm_step(params, xt, state, d_model=d_model)
        return new_state, y

    (h, c, n, m), ys = jax.lax.scan(body, (h0, c0, n0, m0), x.swapaxes(0, 1))
    y = ys.swapaxes(0, 1)
    y = groupnorm_apply(params["norm"], y, groups=n_heads)
    if return_state:
        return y, {"h": h, "c": c, "n": n, "m": m}
    return y


def slstm_decode_init_state(batch: int, d_model: int, dtype=jnp.float32):
    return {
        "h": jnp.zeros((batch, d_model), dtype),
        "c": jnp.zeros((batch, d_model), jnp.float32),
        "n": jnp.zeros((batch, d_model), jnp.float32),
        "m": jnp.full((batch, d_model), -1e30, jnp.float32),
    }


def slstm_decode_apply(params, x, state, *, n_heads: int):
    B, _, d_model = x.shape
    y, (h, c, n, m) = slstm_step(params, x[:, 0],
                                 (state["h"], state["c"], state["n"], state["m"]),
                                 d_model=d_model)
    y = groupnorm_apply(params["norm"], y[:, None, :], groups=n_heads)
    return y, {"h": h, "c": c, "n": n, "m": m}
