"""Rotary position embeddings (RoPE)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    """Inverse frequencies, shape (head_dim // 2,)."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """Apply RoPE.

    x: (..., T, H, D) -- T and H axes in the last three dims.
    positions: (..., T) integer positions broadcastable against x's batch dims.
    """
    d = x.shape[-1]
    inv_freq = rope_frequencies(d, theta)                      # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (..., T, d/2)
    # broadcast over the head axis
    angles = angles[..., None, :]                              # (..., T, 1, d/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
