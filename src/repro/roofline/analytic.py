"""Analytic roofline terms per (arch x shape x mesh).

Why analytic: XLA's compiled cost_analysis() on this backend reports
PER-DEVICE flops and counts while-loop bodies ONCE (verified empirically;
see EXPERIMENTS.md §Dry-run caveats).  Since the framework scans over
superblocks/microbatches/chunks, the HLO numbers undercount by the trip
counts.  The dry-run still proves lowering/sharding and provides the
collective OP INVENTORY; the time terms below are derived analytically
from the same static shapes the dry-run compiles.

All terms are per-chip seconds:
  compute    = FLOPs / (chips * 197 TFLOP/s)
  memory     = HBM bytes touched / (chips-local bytes / 819 GB/s)
  collective = per-chip ICI bytes / 50 GB/s

Collective accounting (per chip, per step):
  TP all-reduce of activation A within a model group: 2*A_local
  FSDP all-gather of params P over the data axes:      P/model_size
  FSDP reduce-scatter of grads:                        P/model_size
  MoE all-to-all (dispatch + return):                  2*tokens*k*d*b/chips
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, InputShape
from repro.models.backbone import cache_window, sublayer_specs
from repro.roofline.analysis import HBM_BW, ICI_BW, PEAK_FLOPS, active_param_count

BYTES = {"float32": 4, "bfloat16": 2}


@dataclasses.dataclass
class MeshSpec:
    data: int = 16
    model: int = 16
    pod: int = 1

    @property
    def chips(self) -> int:
        return self.data * self.model * self.pod

    @property
    def dsize(self) -> int:
        return self.data * self.pod


def _attn_layers(cfg: ArchConfig) -> int:
    return sum(1 for s in sublayer_specs(cfg) if s["kind"] == "attn") * cfg.n_superblocks


def _moe_layers(cfg: ArchConfig) -> int:
    return sum(1 for s in sublayer_specs(cfg) if s["ffn"] == "moe") * cfg.n_superblocks


def _param_bytes(cfg: ArchConfig) -> float:
    # total params (all experts), not just active
    n = total_param_count(cfg)
    return n * BYTES[cfg.param_dtype]


def total_param_count(cfg: ArchConfig) -> int:
    n = active_param_count(cfg)
    if cfg.moe is not None:
        d = cfg.d_model
        per_moe = 3 * d * cfg.moe.expert_d_ff
        n += _moe_layers(cfg) * per_moe * (cfg.moe.n_experts - cfg.moe.top_k)
    return n


def flops_estimate(cfg: ArchConfig, shape: InputShape) -> float:
    """Parameter flops + attention flops (+3x for backward on train)."""
    B, T = shape.global_batch, shape.seq_len
    decode = shape.kind == "decode"
    tokens = B * (1 if decode else T)
    mult = 3.0 if shape.kind == "train" else 1.0
    n_active = active_param_count(cfg)
    flops = mult * 2.0 * n_active * tokens

    # attention: q @ k^T and p @ v
    hd = cfg.resolved_head_dim
    H = cfg.n_heads
    L_attn = _attn_layers(cfg)
    window = cfg.sliding_window or (cfg.long_context_window
                                    if shape.name == "long_500k" else 0)
    if decode:
        s_eff = cache_window(cfg, T, long_context=shape.name == "long_500k")
        flops += L_attn * 4.0 * B * s_eff * H * hd
    else:
        s_eff = min(window, T) if window else T
        # causal: average context T/2 (or window)
        avg_ctx = s_eff if window and window < T else T / 2
        flops += mult * L_attn * 4.0 * B * T * avg_ctx * H * hd
    return flops


def memory_bytes_per_chip(cfg: ArchConfig, shape: InputShape, mesh: MeshSpec,
                          *, n_micro: int = 1, fsdp_serve: bool = False) -> float:
    """HBM bytes touched per chip per step (coarse napkin model)."""
    B, T = shape.global_batch, shape.seq_len
    pb = _param_bytes(cfg)
    act_b = 2  # activations bf16 in compute
    d = cfg.d_model
    if shape.kind == "train":
        # FSDP: per microbatch, gathered params are read fwd+bwd from HBM
        p_read = 2 * n_micro * pb / mesh.model
        # updates: read+write grads, momentum, params (sharded over chips)
        p_upd = 5 * pb / mesh.chips
        # remat activations: write fwd + read bwd + recompute write
        act = 3 * cfg.n_layers * B * T * d * act_b / mesh.chips
        return p_read + p_upd + act
    if shape.kind == "prefill":
        p_read = pb / (mesh.chips if fsdp_serve else mesh.model)
        act = 2 * cfg.n_layers * B * T * d * act_b / mesh.chips
        cache = _cache_bytes(cfg, shape) / mesh.chips
        return p_read + act + cache
    # decode: params + full cache read per token
    p_read = pb / mesh.model  # gathered (fsdp_serve) or resident: read once
    cache = _cache_bytes(cfg, shape) / mesh.chips
    return p_read + cache


def _cache_bytes(cfg: ArchConfig, shape: InputShape) -> float:
    B, T = shape.global_batch, shape.seq_len
    S = cache_window(cfg, T, long_context=shape.name == "long_500k")
    hd = cfg.resolved_head_dim
    b = BYTES[cfg.param_dtype]
    kv = _attn_layers(cfg) * B * S * cfg.n_kv_heads * hd * 2 * b
    if cfg.encdec is not None:
        kv += cfg.n_layers * B * cfg.encdec.n_frames * cfg.n_heads * hd * 2 * b
    specs = sublayer_specs(cfg)
    n_mamba = sum(1 for s in specs if s["kind"] == "mamba") * cfg.n_superblocks
    if n_mamba:
        di = cfg.hybrid.expand * cfg.d_model
        kv += n_mamba * B * di * cfg.hybrid.d_state * 4
    n_ml = sum(1 for s in specs if s["kind"] == "mlstm") * cfg.n_superblocks
    if n_ml:
        kv += n_ml * B * cfg.n_heads * hd * hd * 4
    return kv


def collective_bytes_per_chip(cfg: ArchConfig, shape: InputShape,
                              mesh: MeshSpec, *, n_micro: int = 1,
                              fsdp_serve: bool = False) -> float:
    B, T = shape.global_batch, shape.seq_len
    decode = shape.kind == "decode"
    tokens_l = B * (1 if decode else T) / mesh.dsize   # per model-group tokens
    d = cfg.d_model
    ab = 2  # bf16 activations
    pb = _param_bytes(cfg)
    L = cfg.n_layers
    n_moe = _moe_layers(cfg)
    total = 0.0
    if shape.kind == "train":
        # FSDP param gathers fwd+bwd + grad reduce-scatter, per microbatch
        total += n_micro * 3 * pb / mesh.model
        # TP all-reduce: 2 per layer fwd + 2 bwd, each 2*A_local per chip
        a_loc = (B / n_micro / mesh.dsize) * T * d * ab
        total += n_micro * L * 4 * 2 * a_loc
        # MoE all-to-all both ways per moe layer (fwd + bwd)
        if n_moe:
            tk = (B / n_micro) * T * cfg.moe.top_k * d * ab
            total += n_micro * n_moe * 2 * 2 * tk / mesh.chips
        if mesh.pod > 1:
            total += pb / mesh.chips  # cross-pod grad reduce share
        return total
    # inference
    if fsdp_serve:
        total += pb / mesh.model          # per-layer weight gathers
    a_loc = tokens_l * d * ab
    total += L * 2 * 2 * a_loc            # 2 TP all-reduces per layer
    if n_moe:
        tk = B * (1 if decode else T) * cfg.moe.top_k * d * ab
        total += n_moe * 2 * tk / mesh.chips
    return total


def strategy_roofline(cfg: ArchConfig, shape: InputShape, *, chips: int = 256,
                      tp: int = 16, fsdp: bool = True, n_micro: int = 1,
                      expert_resident: bool = False,
                      replicated_params: bool = False) -> dict:
    """Roofline terms under an explicit sharding strategy (§Perf).

    tp: tensor-parallel degree (1 = pure DP; chips = all-chip TP).
    fsdp: weight/grad/opt sharding over the data axes (train) or 2D weight
      gathers (serve).  replicated_params (tp=1, no fsdp): grads all-reduce.
    expert_resident: 2D expert placement — expert weights never gathered;
      only token all-to-all moves.
    """
    dsize = chips // max(tp, 1)
    B, T = shape.global_batch, shape.seq_len
    decode = shape.kind == "decode"
    pb = _param_bytes(cfg)
    d = cfg.d_model
    ab = 2
    L = cfg.n_layers
    n_moe = _moe_layers(cfg)
    eb = 0.0
    if cfg.moe is not None:
        eb = (3 * d * cfg.moe.expert_d_ff * cfg.moe.n_experts
              * _moe_layers(cfg) * BYTES[cfg.param_dtype])
    pb_gathered = pb - (eb if expert_resident else 0.0)

    flops = flops_estimate(cfg, shape)
    coll = 0.0
    mem = 0.0
    if shape.kind == "train":
        if replicated_params:
            coll += 2 * pb                     # grad all-reduce (ring: 2x)
            mem += 2 * n_micro * pb + 5 * pb   # reads fwd/bwd + update
        elif fsdp:
            coll += n_micro * 3 * pb_gathered / max(tp, 1)
            mem += 2 * n_micro * pb_gathered / max(tp, 1) + 5 * pb / chips
            if expert_resident:
                mem += 2 * n_micro * eb / chips
        if tp > 1:
            a_loc = (B / n_micro / dsize) * T * d * ab
            coll += n_micro * L * 4 * 2 * a_loc
        if n_moe:
            tk = (B / n_micro) * T * cfg.moe.top_k * d * ab
            coll += n_micro * n_moe * 2 * 2 * tk / chips
        mem += 3 * L * B * T * d * ab / chips
    else:
        if fsdp and not expert_resident:
            coll += pb_gathered / max(tp, 1)
            mem += pb_gathered / max(tp, 1)
        else:
            mem += pb / chips if tp == chips else pb / max(tp, 1)
        tokens_l = B * (1 if decode else T) / max(dsize, 1)
        if tp > 1:
            coll += L * 2 * 2 * tokens_l * d * ab
        if n_moe:
            tk = B * (1 if decode else T) * cfg.moe.top_k * d * ab
            coll += n_moe * 2 * tk / chips
        mem += _cache_bytes(cfg, shape) / chips
        if not decode:
            mem += 2 * L * B * T * d * ab / chips
    terms = {
        "compute_s": flops / (chips * PEAK_FLOPS),
        "memory_s": mem / HBM_BW,
        "collective_s": coll / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())
    return {**terms, "dominant": dominant, "step_s_bound": step_s,
            "flops": flops, "chips": chips, "tp": tp, "n_micro": n_micro}


def analytic_roofline(cfg: ArchConfig, shape: InputShape,
                      mesh: MeshSpec | None = None, *, n_micro: int = 1,
                      fsdp_serve: bool = False) -> dict:
    mesh = mesh or MeshSpec()
    flops = flops_estimate(cfg, shape)
    mem = memory_bytes_per_chip(cfg, shape, mesh, n_micro=n_micro,
                                fsdp_serve=fsdp_serve)
    coll = collective_bytes_per_chip(cfg, shape, mesh, n_micro=n_micro,
                                     fsdp_serve=fsdp_serve)
    terms = {
        "compute_s": flops / (mesh.chips * PEAK_FLOPS),
        "memory_s": mem / HBM_BW,
        "collective_s": coll / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    return {"flops": flops, "hbm_bytes_per_chip": mem,
            "collective_bytes_per_chip": coll,
            **terms, "dominant": dominant, "chips": mesh.chips}
