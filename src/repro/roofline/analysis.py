"""Roofline analysis from a compiled dry-run artifact (no real hardware).

Three terms per (arch x mesh), in seconds:
  compute    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory     = HLO_bytes / (chips * HBM_bw)
  collective = collective_bytes / (chips * link_bw)

cost_analysis() gives FLOPs/bytes; collective bytes are parsed out of the
compiled HLO text by summing operand sizes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops.

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g. "f32[16,128,1024]{2,1,0}" — capture dtype + dims
_SHAPE_RE = re.compile(r"\b(f64|f32|bf16|f16|f8e4m3|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op, by kind.

    HLO lines look like:
      %ag = f32[16,1024]{...} all-gather(%x), replica_groups=...
    The result shape (left of '=') is what moves on the wire (upper bound
    for all-gather; exact for all-to-all/permute; 2x-ish for all-reduce's
    ring but we report the logical payload).
    """
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+)$", stripped)
        if not m:
            continue
        rhs = m.group(1)
        for kind in _COLLECTIVES:
            # match the op name as the instruction (not in metadata)
            if re.search(rf"\)?\s{kind}(?:-start|-done)?\(", " " + rhs) or \
               rhs.startswith(kind + "(") or f" {kind}(" in rhs.split("metadata")[0]:
                if f"{kind}-done" in rhs:
                    break  # counted at -start
                shapes = _SHAPE_RE.findall(rhs.split(f"{kind}")[0])
                nbytes = sum(_shape_bytes(d, s) for d, s in shapes)
                out[kind] += nbytes
                counts[kind] += 1
                break
    return {"bytes_by_kind": out, "counts": counts,
            "total_bytes": sum(out.values())}


def analyze_compiled(compiled, *, mesh=None) -> dict:
    """Roofline record from a jax compiled object."""
    n_chips = 1
    if mesh is not None:
        for v in mesh.shape.values():
            n_chips *= v
    ca_list = compiled.cost_analysis()
    ca = ca_list[0] if isinstance(ca_list, (list, tuple)) else ca_list
    flops = float(ca.get("flops", 0.0))
    hbm_bytes = float(ca.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)

    compute_s = flops / (n_chips * PEAK_FLOPS)
    memory_s = hbm_bytes / (n_chips * HBM_BW)
    collective_s = coll["total_bytes"] / (n_chips * ICI_BW)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    return {
        "flops": flops,
        "hbm_bytes": hbm_bytes,
        "collectives": coll,
        "roofline": {**terms, "dominant": dominant, "chips": n_chips},
    }


def model_flops(cfg, shape) -> float:
    """6 * N_active * D tokens (training; inference: 2*N_active*D)."""
    n_active = active_param_count(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


def active_param_count(cfg) -> int:
    """Parameters touched per token (MoE: top_k experts only)."""
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab
    hd = cfg.resolved_head_dim
    total = V * d  # embed (readout tied or separate counted once)
    if not cfg.tie_embeddings:
        total += V * d
    from repro.models.backbone import sublayer_specs
    specs = sublayer_specs(cfg)
    per_sb = 0
    for s in specs:
        if s["kind"] == "attn":
            per_sb += d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * d
        elif s["kind"] == "mamba":
            h = cfg.hybrid
            di = h.expand * d
            per_sb += d * 2 * di + di * d + di * (max(1, d // 16) + 2 * h.d_state) \
                + max(1, d // 16) * di
        elif s["kind"] in ("mlstm", "slstm"):
            per_sb += 4 * d * d if s["kind"] == "mlstm" else 8 * d * d
        if s["ffn"] == "dense":
            per_sb += 3 * d * cfg.d_ff if cfg.norm == "rmsnorm" else 2 * d * cfg.d_ff
        elif s["ffn"] == "moe":
            per_sb += 3 * d * cfg.moe.expert_d_ff * cfg.moe.top_k
            if cfg.moe.dense_residual_ff:
                per_sb += 3 * d * cfg.moe.dense_residual_ff
            if cfg.moe.shared_expert_ff:
                per_sb += 3 * d * cfg.moe.shared_expert_ff
            per_sb += d * cfg.moe.n_experts  # router
    total += per_sb * cfg.n_superblocks
    if cfg.encdec is not None:
        enc = cfg.encdec.n_encoder_layers * (4 * d * d + 2 * d * cfg.d_ff)
        total += enc + cfg.n_layers * (4 * d * d)   # cross-attention
    return int(total)
