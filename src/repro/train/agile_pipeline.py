"""The staged AgileNN training pipeline (paper §3-§5).

Stage A  pre-processing: train [extractor + reference NN] end-to-end with
         plain CE to high accuracy; freeze the reference NN; keep the
         extractor weights as the joint-training initialization (§3.2).
Stage B  Algorithm 1: rank channels by top-k likelihood under XAI
         importance; build the mapping permutation (§5).
Stage C  joint training of extractor + Local NN + Remote NN + alpha +
         quantizer with L = lam*L_pred + (1-lam)*(L_skew + L_dis) (§4.2).
Stage D  deployment: fold the mapping layer into the extractor (§5).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.agilenn_cifar import AgileNNConfig
from repro.core.agile import (
    agile_forward,
    agile_loss,
    batch_importance,
    cross_entropy,
    extract_features,
    init_agile_params,
    reference_predict_fn,
)
from repro.core.channel_selection import (
    build_mapping_permutation,
    select_initial_channels,
    topk_channel_counts,
)
from repro.core.skewness import achieved_skewness, disorder_rate
from repro.core.xai import evaluate_importance
from repro.data.synthetic import ImageDatasetSpec, SyntheticImages
from repro.models.cnn import extractor_apply, extractor_init, reference_nn_apply, reference_nn_init
from repro.nn.module import split_keys
from repro.optim.sgd import sgd_init, sgd_update


# ------------------------------------------------------------- stage A -----
def pretrain_reference(cfg: AgileNNConfig, data: SyntheticImages, key, *,
                       steps: int = 300, batch_size: int = 64, lr: float = 0.05,
                       log_every: int = 0):
    """Returns (extractor_params, reference_params, final train accuracy)."""
    kk = split_keys(key, ["ex", "ref"])
    ex = extractor_init(kk["ex"], channels=cfg.extractor_channels,
                        n_layers=cfg.extractor_layers)
    ref = reference_nn_init(kk["ref"], cfg.extractor_channels, cfg.n_classes,
                            width=cfg.reference_width, blocks=cfg.reference_blocks)
    params = {"ex": ex, "ref": ref}
    opt = sgd_init(params)

    def loss_fn(p, images, labels):
        feats = extractor_apply(p["ex"], images)
        logits = reference_nn_apply(p["ref"], feats)
        loss = cross_entropy(logits, labels)
        acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
        return loss, acc

    @jax.jit
    def step_fn(p, o, images, labels, lr):
        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(p, images, labels)
        p, o = sgd_update(p, grads, o, lr=lr)
        return p, o, loss, acc

    acc = 0.0
    for i in range(steps):
        images, labels = data.batch(batch_size, seed=i)
        cur_lr = lr * (0.1 if i > steps * 0.7 else 1.0)
        params, opt, loss, acc = step_fn(params, opt, images, labels, cur_lr)
        if log_every and i % log_every == 0:
            print(f"[stage A] step {i} loss {float(loss):.3f} acc {float(acc):.3f}")
    return params["ex"], params["ref"], float(acc)


# ------------------------------------------------------------- stage B -----
def run_channel_selection(cfg: AgileNNConfig, extractor_params, ref_params,
                          data: SyntheticImages, *, n_batches: int = 8,
                          batch_size: int = 64, method: str = "ig") -> np.ndarray:
    """Algorithm 1 over the training set; returns the mapping permutation."""
    predict = reference_predict_fn(cfg, ref_params)

    @jax.jit
    def counts_for(images, labels):
        feats = extractor_apply(extractor_params, images)
        imp = evaluate_importance(predict, feats, labels, method=method,
                                  steps=cfg.agile.ig_steps)
        return topk_channel_counts(imp, cfg.agile.k)

    counts = jnp.zeros((cfg.extractor_channels,))
    total = 0
    for i in range(n_batches):
        images, labels = data.batch(batch_size, seed=1000 + i)
        counts = counts + counts_for(images, labels)
        total += batch_size
    p = np.asarray(counts) / total
    ranking = np.argsort(-p, kind="stable")
    selected = ranking[:cfg.agile.k]
    return build_mapping_permutation(selected, cfg.extractor_channels)


# ------------------------------------------------------------- stage C -----
def joint_train(cfg: AgileNNConfig, params, ref_params,
                data: SyntheticImages, *, steps: int = 400,
                batch_size: int = 64, lr: float = 0.02,
                ref_track_lr: float = 0.01,
                xai_method: str = "ig", log_every: int = 0,
                record_curve: bool = False, ordering: str = "disorder",
                lam: "float | None" = None):
    """Joint training with the unified loss.

    The reference NN is *tracked*: each step it takes one CE step on the
    current (stop-gradient) features so its predictions — and therefore
    the XAI importance evaluation — stay accurate while the extractor
    drifts.  (The paper requires an accurate reference for correct XAI
    (§2.2) but does not spell out drift handling; see DESIGN.md.)

    Returns (params, ref_params, history).
    """
    params = dict(params)
    mapping = params.pop("mapping")   # integer permutation: not trainable
    opt = sgd_init(params)
    ref_opt = sgd_init(ref_params)

    @partial(jax.jit, static_argnames=("method",))
    def step_fn(p, o, rp, ro, images, labels, lr, method="ig"):
        def loss_fn(pp):
            return agile_loss(cfg, {**pp, "mapping": mapping}, rp,
                              images, labels, xai_method=method,
                              ordering=ordering, lam=lam)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(p)
        p, o = sgd_update(p, grads, o, lr=lr)
        # reference tracking step on the fresh extractor output
        feats = jax.lax.stop_gradient(
            extract_features(cfg, {**p, "mapping": mapping}, images))

        def ref_loss(rpp):
            return cross_entropy(reference_nn_apply(rpp, feats), labels)

        rgrads = jax.grad(ref_loss)(rp)
        rp, ro = sgd_update(rp, rgrads, ro, lr=ref_track_lr)
        return p, o, rp, ro, loss, metrics

    history = []
    for i in range(steps):
        images, labels = data.batch(batch_size, seed=20_000 + i)
        cur_lr = lr * (0.1 if i > steps * 0.7 else 1.0)
        params, opt, ref_params, ref_opt, loss, metrics = step_fn(
            params, opt, ref_params, ref_opt, images, labels, cur_lr,
            method=xai_method)
        if record_curve or (log_every and i % log_every == 0):
            row = {k: float(v) for k, v in metrics.items()}
            row["step"] = i
            row["loss"] = float(loss)
            history.append(row)
            if log_every and i % log_every == 0:
                print(f"[stage C] step {i} loss {row['loss']:.3f} "
                      f"acc {row['accuracy']:.3f} skew_loss {row['loss_skewness']:.3f}")
    params = dict(params)
    params["mapping"] = mapping
    return params, ref_params, history


# ------------------------------------------------------------- stage D -----
def finalize_for_deployment(cfg: AgileNNConfig, params):
    """Fold the mapping permutation into the extractor's last conv (the
    mapping layer is discarded, §5 Figure 12)."""
    from repro.core.channel_selection import fold_permutation_into_conv
    perm = np.asarray(params["mapping"])
    out = jax.tree_util.tree_map(lambda x: x, params)  # shallow copy
    out = dict(out)
    convs = list(out["extractor"]["convs"])
    convs[-1] = fold_permutation_into_conv(convs[-1], perm)
    out["extractor"] = {"convs": convs}
    out["mapping"] = jnp.arange(cfg.extractor_channels, dtype=jnp.int32)
    return out


# ----------------------------------------------------------- evaluation ----
def evaluate(cfg: AgileNNConfig, params, ref_params, data: SyntheticImages, *,
             n_batches: int = 4, batch_size: int = 128,
             xai_method: str = "ig", alpha_override=None):
    """Test-set metrics: accuracy, achieved skewness, disorder rate."""
    predict = reference_predict_fn(cfg, ref_params)

    @jax.jit
    def eval_batch(images, labels):
        logits, internals = agile_forward(cfg, params, images, train=False,
                                          alpha_override=alpha_override)
        acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
        imp = evaluate_importance(predict, internals["features"], labels,
                                  method=xai_method, steps=cfg.agile.ig_steps)
        return acc, achieved_skewness(imp, cfg.agile.k), disorder_rate(imp, cfg.agile.k)

    accs, skews, disorders = [], [], []
    for i in range(n_batches):
        images, labels = data.batch(batch_size, seed=900_000 + i)
        a, s, d = eval_batch(images, labels)
        accs.append(float(a)); skews.append(float(s)); disorders.append(float(d))
    return {"accuracy": float(np.mean(accs)),
            "skewness": float(np.mean(skews)),
            "disorder_rate": float(np.mean(disorders))}


def run_full_pipeline(cfg: AgileNNConfig, *, seed: int = 0,
                      pretrain_steps: int = 300, joint_steps: int = 400,
                      batch_size: int = 64, xai_method: str = "ig",
                      log_every: int = 0, noise: float = 0.35,
                      ordering: str = "disorder", lam: "float | None" = None,
                      random_channels: bool = False):
    """End-to-end stages A-D.  Returns (params, ref_params, report)."""
    data = SyntheticImages(ImageDatasetSpec(
        n_classes=cfg.n_classes, image_size=cfg.image_size, noise=noise, seed=seed))
    key = jax.random.PRNGKey(seed)
    kk = split_keys(key, ["pre", "joint"])

    ex_params, ref_params, ref_acc = pretrain_reference(
        cfg, data, kk["pre"], steps=pretrain_steps, batch_size=batch_size,
        log_every=log_every)
    if random_channels:   # Figure-11 ablation: arbitrary initial channels
        import numpy as _np
        rng = _np.random.RandomState(seed + 1)
        sel = rng.permutation(cfg.extractor_channels)[:cfg.agile.k]
        mapping = build_mapping_permutation(sel, cfg.extractor_channels)
    else:
        mapping = run_channel_selection(cfg, ex_params, ref_params, data,
                                        method=xai_method)
    from repro.core.channel_selection import permute_reference_stem
    ref_params = permute_reference_stem(ref_params, mapping)
    params = init_agile_params(cfg, kk["joint"], extractor_params=ex_params)
    params["mapping"] = jnp.asarray(mapping)
    params, ref_params, history = joint_train(
        cfg, params, ref_params, data, steps=joint_steps,
        batch_size=batch_size, xai_method=xai_method, log_every=log_every,
        ordering=ordering, lam=lam, record_curve=True)
    params = finalize_for_deployment(cfg, params)
    report = evaluate(cfg, params, ref_params, data, xai_method=xai_method)
    report["reference_accuracy"] = ref_acc
    return params, ref_params, report, history, data
