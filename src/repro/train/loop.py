"""Generic training loop: step function + data loader + metrics +
periodic checkpointing.

Used by the end-to-end drivers; the distributed launcher wires the same
loop around the jit'd sharded step from launch.steps.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint.io import save_checkpoint


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: int = 0


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    log_every: int = 20
    ckpt_every: int = 0
    ckpt_path: str = ""


def run_training(state: TrainState, step_fn: Callable, data_iter, *,
                 loop: LoopConfig, on_log: Optional[Callable] = None) -> TrainState:
    """step_fn(params, opt_state, batch) -> (params, opt_state, metrics).

    Returns the final TrainState; metrics history attached as .history.
    """
    history = []
    t0 = time.time()
    for i in range(state.step, loop.total_steps):
        batch = next(data_iter)
        state.params, state.opt_state, metrics = step_fn(
            state.params, state.opt_state, batch)
        state.step = i + 1
        if loop.log_every and (i % loop.log_every == 0
                               or i == loop.total_steps - 1):
            row = {k: float(v) for k, v in metrics.items()
                   if np.ndim(v) == 0}
            row.update(step=i, wall_s=round(time.time() - t0, 1))
            history.append(row)
            if on_log:
                on_log(row)
        if loop.ckpt_every and loop.ckpt_path and \
                (i + 1) % loop.ckpt_every == 0:
            save_checkpoint(loop.ckpt_path, state.params)
    state.history = history  # type: ignore[attr-defined]
    return state
