"""Telemetry overhead benchmark: the enabled-path cost of observing.

One pinned scheduler workload (16 mixed-length requests, 4-slot pool)
drains twice per round — telemetry disabled and fully enabled —
alternating within each round (paired min-of-3, like `serve_sharded`)
so box noise hits both modes.  The row is the enabled path's wall-clock
overhead as a percentage of the uninstrumented drain; the ``--compare``
gate holds it under an *absolute* 5% ceiling (machine speed cancels out
of the ratio the row encodes, so no baseline ratio math applies).

The drain also re-asserts the harder contract inside the benchmark:
greedy tokens from the instrumented run are bit-identical to the
uninstrumented ones — instrumentation only reads.

The workload is pinned (no --smoke shrink) so smoke rows stay
comparable to the committed baseline.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import paired_best_of

KEY = jax.random.PRNGKey(0)

N_REQS = 16
MAX_NEW = 6
LENGTHS = (8, 16, 11, 5)
REPS = 3


def _requests(cfg):
    from repro.serve.engine import Request
    rng = np.random.RandomState(0)
    return [Request(tokens=rng.randint(0, cfg.vocab,
                                       LENGTHS[i % len(LENGTHS)]),
                    max_new_tokens=MAX_NEW) for i in range(N_REQS)]


def telemetry_rows() -> list[tuple]:
    from repro.configs import get_config
    from repro.models import backbone as bb
    from repro.serve.scheduler import ContinuousScheduler, SchedulerConfig
    from repro.serve.telemetry import Telemetry

    cfg = get_config("qwen2-0.5b").reduced()
    params = bb.init_params(cfg, KEY)

    def build(tel: Telemetry) -> ContinuousScheduler:
        sched = ContinuousScheduler(
            cfg, params, max_len=32,
            sched=SchedulerConfig(buckets=(8, 16), max_slots=4,
                                  prefill_group=2, chunk=4),
            telemetry=tel)
        _drain(sched)                      # warm-up: pays the compiles
        return sched

    def _drain(sched) -> tuple:
        rids = [sched.submit(r) for r in _requests(cfg)]
        t0 = time.time()
        outs = sched.run()
        return time.time() - t0, [outs[r].tokens for r in rids]

    scheds = {"off": build(Telemetry(enabled=False)),
              "on": build(Telemetry(enabled=True))}
    tokens: dict = {}

    def timed(mode: str) -> float:
        dt, toks = _drain(scheds[mode])
        for a, b in zip(tokens.setdefault(mode, toks), toks):
            np.testing.assert_array_equal(a, b)   # drains are deterministic
        return dt

    best = paired_best_of({m: (lambda m=m: timed(m)) for m in scheds}, REPS)

    # the no-subscriber contract, re-proven on the benchmark workload:
    # observing the drain must not move a single token
    for a, b in zip(tokens["off"], tokens["on"]):
        np.testing.assert_array_equal(a, b)
    tel_on = scheds["on"].tel
    assert tel_on.trace.spans, "enabled run recorded no spans"

    overhead = max(0.0, (best["on"] - best["off"]) / best["off"] * 100.0)
    pin = (f"{N_REQS} reqs mix {LENGTHS} max_new={MAX_NEW} W=4 "
           f"paired min-of-{REPS}")
    return [("telemetry.overhead_pct", overhead, pin)]
