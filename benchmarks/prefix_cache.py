"""Shared-prefix page cache benchmark: tokens/s at fixed pool memory.

A fleet-shaped workload — N clients whose prompts all open with the same
page-aligned system prompt and diverge in short tails — runs through the
same scheduler twice: prefix sharing OFF (every admission prefills its
whole prompt) and ON (hits seed the resident system-prompt pages and
prefill only the tail).  The pool is identical in both runs (same
max_slots, same KV width), so the throughput delta is purely the
deduplicated prefill work.

``prefix.hit_rate`` is a deterministic output of the pinned workload and
admission schedule (no Poisson interleaving, greedy decode), so the
``--compare`` gate matches it at ratio ~1.0 on any machine and only
moves when the sharing semantics change.  The workload is pinned (no
--smoke shrink) so smoke rows stay comparable to the committed baseline.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import median_of

KEY = jax.random.PRNGKey(0)

N_CLIENTS = 16
SYS_LEN = 128         # four shareable pages at the scheduler's page_size 32
TAILS = (8, 16, 24)
MAX_NEW = 4
REPS = 3              # timed drains per mode; the row is their median


def _requests(cfg):
    from repro.serve.engine import Request
    rng = np.random.RandomState(0)
    sys_prompt = rng.randint(0, cfg.vocab, SYS_LEN)
    return [Request(tokens=np.concatenate(
                [sys_prompt, rng.randint(0, cfg.vocab, rng.choice(TAILS))]),
                    max_new_tokens=MAX_NEW)
            for _ in range(N_CLIENTS)]


def prefix_cache_rows() -> list[tuple]:
    from repro.configs import get_config
    from repro.models import backbone as bb
    from repro.serve.scheduler import ContinuousScheduler, SchedulerConfig

    cfg = get_config("qwen2-0.5b").reduced()
    params = bb.init_params(cfg, KEY)

    bucket = SYS_LEN + 32                  # tails all fit in one page

    def drain(prefix_on: bool) -> tuple[float, object]:
        sched = ContinuousScheduler(
            cfg, params, max_len=bucket + MAX_NEW + 8,
            sched=SchedulerConfig(buckets=(bucket,), max_slots=8,
                                  prefill_group=4, chunk=4,
                                  prefill_segment=0,
                                  prefix_cache=prefix_on))
        reqs = _requests(cfg)

        def once() -> float:
            for r in reqs:
                sched.submit(r)
            t0 = time.time()
            sched.run()
            return time.time() - t0

        once()                             # warm-up drain: pays compiles
        return median_of(once, REPS), sched

    tails = "/".join(str(t) for t in TAILS)
    pin = (f"{N_CLIENTS} reqs shared {SYS_LEN}-tok sys prompt "
           f"tails {tails} max_new={MAX_NEW} W=8")
    toks = N_CLIENTS * MAX_NEW             # greedy, eos_id=-1: full budgets
    dt_off, _ = drain(False)
    dt_on, sched_on = drain(True)
    hr = sched_on.prefix.hit_rate
    assert hr > 0, "shared-prefix workload produced no page hits"
    return [
        ("prefix.hit_rate", hr, f"{pin}, simulated"),
        ("prefix.shared_tokens_per_s", toks / dt_on, f"{pin}, sharing on"),
        ("prefix.unshared_tokens_per_s", toks / dt_off,
         f"{pin}, sharing off"),
    ]
