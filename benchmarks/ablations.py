"""Paper ablation figures: 9 (descent vs disorder loss), 10 (lambda sweep),
11 (Algorithm-1 vs random channel selection), 15 (training convergence)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import QUICK_CFG


def _run(joint_steps=120, **kw):
    from repro.train.agile_pipeline import run_full_pipeline
    return run_full_pipeline(QUICK_CFG, pretrain_steps=60,
                             joint_steps=joint_steps, batch_size=32, **kw)


# -------------------------------------------- Figure 9: ordering losses ----
def fig9_ordering_loss() -> list[tuple]:
    """L_disorder (Eq. 1, relaxed) vs the strawman L_descent (full sort).
    Paper: enforcing the full descending order costs >10% accuracy."""
    rows = []
    for ordering in ("disorder", "descent"):
        _, _, report, _, _ = _run(ordering=ordering)
        rows.append((f"fig9.accuracy@{ordering}", report["accuracy"],
                     f"disorder_rate={report['disorder_rate']:.3f}"))
        rows.append((f"fig9.skewness@{ordering}", report["skewness"], ""))
    return rows


# ------------------------------------------------ Figure 10: lambda --------
def fig10_lambda_sweep() -> list[tuple]:
    """lam in {0.1, 0.3, 0.7}: small lam over-weights skewness and hurts
    accuracy; the paper recommends 0.2-0.4."""
    rows = []
    for lam in (0.1, 0.3, 0.7):
        _, _, report, _, _ = _run(lam=lam)
        rows.append((f"fig10.accuracy@lam{lam}", report["accuracy"],
                     f"skew={report['skewness']:.3f}"))
    return rows


# -------------------------------------- Figure 11: channel pre-selection ---
def fig11_channel_selection() -> list[tuple]:
    """Algorithm-1 likelihood-based initial channels vs random selection.
    Paper: random selection causes learning difficulty from the first
    epochs."""
    rows = []
    for random_channels in (False, True):
        tag = "random" if random_channels else "alg1"
        _, _, report, history, _ = _run(random_channels=random_channels)
        early = [h["loss"] for h in history if h["step"] < 40] or [float("nan")]
        rows.append((f"fig11.accuracy@{tag}", report["accuracy"],
                     f"skew={report['skewness']:.3f}"))
        rows.append((f"fig11.early_loss@{tag}", float(np.mean(early)),
                     "mean loss over first 40 joint steps"))
    return rows


# ------------------------------------------- Figure 15: convergence --------
def fig15_convergence() -> list[tuple]:
    """AgileNN's joint training converges at a rate comparable to plain
    training of the same remote backbone (paper Fig. 15)."""
    import jax
    import jax.numpy as jnp
    from repro.core.agile import cross_entropy
    from repro.core.baselines import train_baseline
    from repro.data.synthetic import ImageDatasetSpec, SyntheticImages
    from repro.models.cnn import remote_nn_apply, remote_nn_init

    cfg = QUICK_CFG
    _, _, report, history, data = _run(joint_steps=120)
    agile_acc = [h["accuracy"] for h in history]
    steps_to_90 = next((h["step"] for h in history if h["accuracy"] >= 0.9),
                       -1)

    # plain training of a same-size CNN on raw images
    key = jax.random.PRNGKey(4)
    p0 = {"net": remote_nn_init(key, 3, cfg.n_classes, width=cfg.remote_width,
                                blocks=cfg.remote_blocks)}

    def plain_loss(p, images, labels):
        logits = remote_nn_apply(p["net"], images)
        acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
        return cross_entropy(logits, labels), {"accuracy": acc}

    accs = []
    params = p0
    from repro.optim.sgd import sgd_init, sgd_update
    opt = sgd_init(params)

    @jax.jit
    def step(p, o, images, labels):
        (loss, m), g = jax.value_and_grad(plain_loss, has_aux=True)(p, images, labels)
        p, o = sgd_update(p, g, o, lr=0.02)
        return p, o, m["accuracy"]

    plain_steps_to_90 = -1
    for i in range(120):
        images, labels = data.batch(32, seed=70_000 + i)
        params, opt, acc = step(params, opt, images, labels)
        if plain_steps_to_90 < 0 and float(acc) >= 0.9:
            plain_steps_to_90 = i
    return [("fig15.agilenn.steps_to_90", steps_to_90,
             "joint training w/ XAI losses"),
            ("fig15.plain.steps_to_90", plain_steps_to_90,
             "plain CNN on raw images"),
            ("fig15.agilenn.final_acc", report["accuracy"], "")]


ABLATIONS = {
    "fig9": fig9_ordering_loss,
    "fig10": fig10_lambda_sweep,
    "fig11": fig11_channel_selection,
    "fig15": fig15_convergence,
}
