"""Streaming SLO benchmark: the overload-robust frontend under a 10x
client stampede, on a virtual clock.

A closed-loop fleet (four clients per priority class, staggered session
starts) is compressed 10x by a scripted `ArrivalBurst` and driven
through a `StreamingFrontend` with a bounded admission queue and an SLO
budget.  Every round of the real scheduler (real compiled programs,
greedy seeded tokens) costs a fixed ``ROUND_S`` of simulated time — the
same modeling move the gateway makes with its device/link models — so
TTFT, inter-token latency, rejection rate and goodput are exact,
machine-independent outputs of the simulation.  The rows' derived
strings therefore end in "simulated": `benchmarks.run.compare_rows`
gates them symmetrically on raw ratio, and any drift is a semantic
change to admission control, not noise.

The workload is pinned (no --smoke shrink) so smoke rows stay
comparable to the committed baseline.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import pctl

KEY = jax.random.PRNGKey(0)

ROUND_S = 0.01          # modeled service time of one scheduler round
N_PER_CLASS = 4         # clients per priority class
N_REQS = 4              # requests per client session
BURST = 10.0            # arrival-compression factor


def stream_slo_rows() -> list[tuple]:
    from repro.configs import get_config
    from repro.models import backbone as bb
    from repro.serve.engine import Request
    from repro.serve.faults import ArrivalBurst, FaultInjector
    from repro.serve.frontend import (
        FrontendConfig, Priority, SimClient, StreamingFrontend,
        VirtualClock, drive_closed_loop)
    from repro.serve.scheduler import SchedulerConfig

    cfg = get_config("qwen2-0.5b").reduced()
    params = bb.init_params(cfg, KEY)
    rng = np.random.RandomState(0)
    clients = []
    for c in range(3 * N_PER_CLASS):
        prio = Priority(c % 3)
        reqs = tuple(
            Request(tokens=rng.randint(0, cfg.vocab,
                                       int(rng.choice((4, 8, 12)))),
                    max_new_tokens=int(6 + rng.randint(0, 5)))
            for _ in range(N_REQS))
        # nominal session starts spread over 1.2 s; the stampede
        # compresses them 10x into the first 120 ms
        clients.append(SimClient(requests=reqs, priority=prio,
                                 start_s=0.1 * c, think_s=0.02))
    clock = VirtualClock()
    fe = StreamingFrontend(
        cfg, params,
        frontend=FrontendConfig(max_queue=6, slo_ms=250.0,
                                class_deadline_ms=(400.0, None, None)),
        sched=SchedulerConfig(buckets=(8, 16), max_slots=4,
                              prefill_group=2, chunk=2),
        max_len=32, seed=0, clock=clock)
    faults = FaultInjector((ArrivalBurst(factor=BURST),), seed=7)
    rep = drive_closed_loop(fe, clients, clock=clock, round_s=ROUND_S,
                            faults=faults)
    assert all(r.status in ("served", "shed", "rejected")
               for r in rep.records), "a request left the ladder"
    inter = rep.ttft_ms(Priority.INTERACTIVE)
    itl = rep.itl_ms()
    pin = (f"{3 * N_PER_CLASS} clients x{N_REQS} reqs stampede(10x) "
           f"maxq=6 slo=250ms round={ROUND_S * 1e3:g}ms")
    return [
        ("stream.ttft_p50_ms", pctl(inter, 50),
         f"{pin} interactive, simulated"),
        ("stream.ttft_p99_ms", pctl(inter, 99),
         f"{pin} interactive, simulated"),
        ("stream.itl_p99_ms", pctl(itl, 99),
         f"{pin} all classes, simulated"),
        ("stream.reject_rate", rep.reject_rate,
         f"{pin} all classes, simulated"),
        ("stream.goodput_rps", rep.goodput_rps,
         f"{pin} all classes, simulated"),
    ]
