"""Offload-gateway benchmark: a 32-client mixed-link fleet end to end.

Two fleet runs share one workload (32 clients round-robined over WiFi /
narrowband / lossy-WiFi links, 6 inferences each, pool width 8): a
static-rate run and an adaptive run against a 30 ms SLO.  The latency and
energy rows are *deterministic* outputs of the seeded simulation — the
``--compare`` gate matches them at ratio ~1.0 on any machine and only
moves when the subsystem's semantics change — while ``clients_per_s`` is
the wall-clock throughput of the real pipeline (payload codecs, event
loop, batched Remote-NN calls).  The workload is pinned (no --smoke
shrink) so smoke rows stay comparable to the committed baseline.
"""
from __future__ import annotations

import jax

from benchmarks.common import best_of


def gateway_rows() -> list[tuple]:
    from repro.configs.agilenn_cifar import gateway_demo_config
    from repro.core.agile import init_agile_params
    from repro.serve.gateway import (
        Fleet, GatewayConfig, OffloadGateway, mixed_fleet)

    cfg = gateway_demo_config()
    params = init_agile_params(cfg, jax.random.PRNGKey(0))
    gw = GatewayConfig(batch_width=8)
    pin = "32 clients mixed links x6 reqs W=8"

    def fresh(slo_ms):
        specs = mixed_fleet(32, n_requests=6, slo_ms=slo_ms)
        return Fleet(cfg, params, specs, seed=0)

    # warm-up run pays the device-pass + remote-step compiles; the best
    # of two timed runs measures the steady pipeline (min-of-N, like
    # timed_us: load only ever adds time, and the latency/energy rows
    # are deterministic so either run yields the same values)
    OffloadGateway(cfg, params, fresh(None), gw).run()
    reports = []

    def timed_run() -> float:
        r = OffloadGateway(cfg, params, fresh(None), gw).run()
        reports.append(r)
        return r.wall_s

    wall = best_of(timed_run, 2)
    report = reports[0]
    report.wall_s = wall
    rows = [
        ("gateway.e2e_latency_p50_ms", report.latency_percentile_ms(50),
         f"{pin} static, simulated"),
        ("gateway.e2e_latency_p99_ms", report.latency_percentile_ms(99),
         f"{pin} static, simulated"),
        ("gateway.device_energy_mj", report.device_energy_mj,
         f"{pin} static, simulated"),
        ("gateway.clients_per_s", report.clients_per_s,
         f"{pin} static, wall-clock"),
    ]

    adaptive = OffloadGateway(cfg, params, fresh(30.0), gw).run()
    rows.append(("gateway.adaptive_e2e_latency_p99_ms",
                 adaptive.latency_percentile_ms(99),
                 f"{pin} SLO=30ms, simulated"))
    rows.append(("gateway.adaptive_payload_bytes",
                 adaptive.summary()["payload_bytes_mean"],
                 f"{pin} SLO=30ms, simulated"))
    rows.append(("gateway.adaptive_device_energy_mj",
                 adaptive.device_energy_mj,
                 f"{pin} SLO=30ms, simulated"))
    return rows
