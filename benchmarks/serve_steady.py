"""Steady-state serving benchmark: a mixed-length Poisson request queue
through the continuous-batching scheduler.

Requests with prompt lengths drawn from {8, 16, 32} arrive as a Poisson
process interleaved with scheduler steps (new arrivals are submitted
between decode segments, the way a serving frontend would).  The first
drain pays all compiles (one prefill per bucket, one inject, one chunk
program); the timed drain measures steady-state decode throughput and
feeds the ``serve.tokens_per_s`` row of BENCH_kernels.json.
"""
from __future__ import annotations

import time

import jax
import numpy as np

import benchmarks.common as common

KEY = jax.random.PRNGKey(0)


def _drain_with_poisson_arrivals(sched, reqs, rng, rate: float) -> float:
    """Submit `reqs` in Poisson(rate)-sized batches between scheduler
    steps; returns wall seconds for the full drain."""
    pending = list(reqs)
    t0 = time.time()
    while pending or sched._queue or any(
            r is not None for r in sched._slot_rid):
        k = min(len(pending), int(rng.poisson(rate)))
        sent, pending = pending[:k], pending[k:]
        for r in sent:
            sched.submit(r)
        sched.step()
    sched.run()                           # collect and forget completions
    return time.time() - t0


def serve_steady_rows() -> list[tuple]:
    from repro.configs import get_config
    from repro.models import backbone as bb
    from repro.serve.engine import Request, ServeEngine
    from repro.serve.scheduler import ContinuousScheduler, SchedulerConfig

    smoke = getattr(common, "SMOKE", False)
    n_requests = 8 if smoke else 24
    max_new = 6 if smoke else 16
    lengths = (8, 16, 32)

    cfg = get_config("qwen2-0.5b").reduced()
    params = bb.init_params(cfg, KEY)
    sched = ContinuousScheduler(
        cfg, params, max_len=max(lengths) + max_new + 8,
        sched=SchedulerConfig(buckets=lengths, max_slots=8,
                              prefill_group=4, chunk=4))

    rng = np.random.RandomState(0)
    reqs = [Request(tokens=rng.randint(0, cfg.vocab, rng.choice(lengths)),
                    max_new_tokens=max_new) for _ in range(n_requests)]

    # warm-up drain: compiles per-bucket prefill + inject + chunk programs
    _drain_with_poisson_arrivals(sched, reqs, np.random.RandomState(1),
                                 rate=3.0)
    dt = _drain_with_poisson_arrivals(sched, reqs, np.random.RandomState(1),
                                      rate=3.0)
    toks = n_requests * max_new           # greedy, eos_id=-1: full budgets
    rows = [
        ("serve.tokens_per_s", toks / dt,
         f"{n_requests} reqs Poisson mix {lengths} max_new={max_new}"),
        ("serve.drain_ms", dt * 1e3,
         f"steady-state drain, {n_requests} reqs max_new={max_new}"),
    ]

    # equal-length fast path at the same token budget, as the scale bar
    eng = ServeEngine(cfg, params, max_len=max(lengths) + max_new + 8)
    equal = [Request(tokens=rng.randint(0, cfg.vocab, 16),
                     max_new_tokens=max_new) for _ in range(n_requests)]
    eng.generate(equal)                   # compile
    t0 = time.time()
    eng.generate(equal)
    dt_eq = time.time() - t0
    rows.append(("serve.equal_len_tokens_per_s", toks / dt_eq,
                 f"{n_requests} equal-length reqs, single while_loop"))

    # chunked prefill: a queue mixing short prompts with 128-bucket
    # admissions that stage in 32-token segments between decode chunks
    n_long = 4 if smoke else 12
    long_lengths = (8, 16, 100, 128)
    sched_long = ContinuousScheduler(
        cfg, params, max_len=128 + max_new + 8,
        sched=SchedulerConfig(buckets=(8, 16, 32, 64, 128), max_slots=8,
                              prefill_group=4, chunk=4, prefill_segment=32))
    rng2 = np.random.RandomState(2)
    long_reqs = [Request(tokens=rng2.randint(0, cfg.vocab,
                                             rng2.choice(long_lengths)),
                         max_new_tokens=max_new) for _ in range(n_long)]
    _drain_with_poisson_arrivals(sched_long, long_reqs,
                                 np.random.RandomState(3), rate=2.0)
    dt_long = _drain_with_poisson_arrivals(sched_long, long_reqs,
                                           np.random.RandomState(3),
                                           rate=2.0)
    rows.append(("serve.chunked_prefill_tokens_per_s",
                 n_long * max_new / dt_long,
                 f"{n_long} reqs mix {long_lengths}, 32-token segments"))
    return rows
