"""Chaos benchmark: the offload gateway under a scripted fault schedule.

Two seeded fleet runs share one pinned workload (16 clients round-robined
over WiFi / narrowband / lossy-WiFi links, 6 inferences each, pool width
8, 150 ms request deadlines):

  * a *chaos* run — a 200 ms mid-run blackout, Gilbert–Elliott burst
    loss, payload corruption and a gateway slot-pool stall all at once —
    measuring how far down the degradation ladder the fleet steps
    (fallback / shed / degraded rates, deadline misses, tail latency);
  * a *total blackout* run — every transmit attempt lost for the whole
    run — pinning the floor of the ladder: every request must resolve as
    a Local-NN fallback (nothing hangs) and the accuracy proxy is the
    local path's accuracy alone.

A third pinned run — the *stampede* — is the overload scenario: a 10x
`ArrivalBurst` compresses every client's arrivals into the head of the
run while a `LinkDegrade` throttles the links, against a gateway with a
bounded admission queue.  It asserts the overload contract: every
request resolves to exactly one degradation-ladder rung (served /
degraded / shed / rejected / fallback — nothing hangs, nothing buffers
unboundedly) and pins the rejected-rung rates.

Every row is a *deterministic* output of the seeded simulation (fault
randomness lives in the injector's per-client streams; the stampede's
arrival compression is closed-form), so the ``--compare`` gate matches
them at ratio ~1.0 on any machine and only moves when the failure
semantics change.  The workload is pinned (no --smoke shrink) so smoke
rows stay comparable to the committed baseline.
"""
from __future__ import annotations

import jax


def faults_rows() -> list[tuple]:
    from repro.configs.agilenn_cifar import gateway_demo_config
    from repro.core.agile import init_agile_params
    from repro.serve.faults import (
        ArrivalBurst, Blackout, BurstLoss, FaultInjector, GatewayStall,
        LinkDegrade, PayloadCorruption,
    )
    from repro.serve.gateway import (
        Fleet, GatewayConfig, OffloadGateway, mixed_fleet)

    cfg = gateway_demo_config()
    params = init_agile_params(cfg, jax.random.PRNGKey(0))
    gw = GatewayConfig(batch_width=8)
    pin = "16 clients x6 reqs W=8 deadline=150ms"

    def run(schedule, gw=gw) -> "object":
        specs = mixed_fleet(16, n_requests=6, deadline_ms=150.0)
        fleet = Fleet(cfg, params, specs, seed=0)
        inj = FaultInjector(schedule, seed=7)
        return OffloadGateway(cfg, params, fleet, gw, faults=inj).run()

    chaos = run((
        Blackout(0.05, 0.25),
        BurstLoss(0.0, 1.0, p_good_bad=0.2, p_bad_good=0.3),
        PayloadCorruption(0.0, 1.0, prob=0.25),
        GatewayStall(0.10, 0.30, stall_s=0.02),
    ))
    n = len(chaos.traces)
    fleet_reqs = 16 * 6
    assert n == fleet_reqs, \
        f"chaos run resolved {n}/{fleet_reqs} requests — a fault path hung"

    blackout = run((Blackout(),))
    assert len(blackout.traces) == fleet_reqs, \
        "total blackout left requests unresolved"
    assert blackout.fallback_rate == 1.0, \
        "total blackout must resolve every request as a Local-NN fallback"

    # stampede: 10x arrival compression + throttled links against a
    # bounded admission queue — the overload-contract pin
    stampede = run(
        (ArrivalBurst(factor=10.0),
         LinkDegrade(bandwidth_scale=0.5, extra_loss=0.1)),
        gw=GatewayConfig(batch_width=8, max_queue=4))
    assert len(stampede.traces) == fleet_reqs, \
        "stampede left requests unresolved — admission or queue hung"
    ladder = {"served", "degraded", "shed", "rejected", "fallback"}
    bad = {tr.status for tr in stampede.traces} - ladder
    assert not bad, f"stampede produced off-ladder statuses {bad}"
    assert stampede.rejected_rate > 0.0, \
        "a 10x stampede into a 4-deep queue must reject at admission"

    sched = "blackout+burst+corrupt+gwstall"
    stam = "stampede(10x)+degrade maxq=4"
    return [
        ("faults.fallback_rate", chaos.fallback_rate,
         f"{pin} {sched}, simulated"),
        ("faults.deadline_miss_rate", chaos.deadline_miss_rate,
         f"{pin} {sched}, simulated"),
        ("faults.degraded_rate", chaos.degraded_rate,
         f"{pin} {sched}, simulated"),
        ("faults.e2e_p99_ms", chaos.latency_percentile_ms(99),
         f"{pin} {sched}, simulated"),
        ("faults.blackout_accuracy_proxy", blackout.summary()["accuracy"],
         f"{pin} total blackout, simulated"),
        ("faults.stampede_rejected_rate", stampede.rejected_rate,
         f"{pin} {stam}, simulated"),
        ("faults.stampede_served_rate", stampede.status_rate("served"),
         f"{pin} {stam}, simulated"),
        ("faults.stampede_e2e_p99_ms", stampede.latency_percentile_ms(99),
         f"{pin} {stam}, simulated"),
    ]
