"""Roofline benchmark (§Roofline deliverable).

Primary terms come from the ANALYTIC model (repro.roofline.analytic) —
XLA's cost_analysis on this backend reports per-device flops with loop
bodies counted once, so the compiled numbers undercount scanned programs
(verified; see EXPERIMENTS.md).  The dry-run JSON supplies the structural
evidence: per-device HLO flops/bytes and the collective op inventory
(which all-gather/all-reduce/all-to-all/etc. the sharding lowered to).
"""
from __future__ import annotations

import json
import os

from repro.configs import get_config
from repro.configs.shapes import get_shape
from repro.launch.steps import n_microbatches
from repro.roofline.analysis import model_flops
from repro.roofline.analytic import MeshSpec, analytic_roofline, total_param_count

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SKIPS = {("whisper-tiny", "long_500k")}


def load_results(path: str = None) -> list[dict]:
    path = path or os.path.join(REPO, "dryrun_single_pod.json")
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def _hlo_evidence(path=None) -> dict:
    out = {}
    for rec in load_results(path):
        if "collectives" in rec:
            out[(rec["arch"], rec["shape"])] = rec
    return out


def _fsdp_serve(cfg) -> bool:
    pb = total_param_count(cfg) * (2 if cfg.param_dtype == "bfloat16" else 4)
    return pb / 2**30 / 16 > 12.0


def full_table(mesh: MeshSpec | None = None, *, with_hlo: bool = True):
    """[(arch, shape, analytic dict, hlo rec or None)] for all 40 pairs."""
    mesh = mesh or MeshSpec()
    hlo = _hlo_evidence() if with_hlo else {}
    rows = []
    from repro.configs import ASSIGNED_ARCHS
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape_name in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            if (arch, shape_name) in SKIPS:
                rows.append((arch, shape_name, None, None))
                continue
            shape = get_shape(shape_name)
            r = analytic_roofline(cfg, shape, mesh,
                                  n_micro=n_microbatches(cfg, shape),
                                  fsdp_serve=_fsdp_serve(cfg) and shape.kind != "train")
            rows.append((arch, shape_name, r, hlo.get((arch, shape_name))))
    return rows


def roofline_rows() -> list[tuple]:
    out = []
    for arch, shape_name, r, hlo in full_table():
        name = f"roofline.{arch}.{shape_name}"
        if r is None:
            out.append((name, 0.0, "skipped (see DESIGN.md)"))
            continue
        cfg, shape = get_config(arch), get_shape(shape_name)
        useful = model_flops(cfg, shape) / max(r["flops"], 1.0)
        out.append((f"{name}.compute_s", r["compute_s"], f"dominant={r['dominant']}"))
        out.append((f"{name}.memory_s", r["memory_s"], ""))
        coll_kinds = ""
        if hlo:
            kinds = {k: v for k, v in hlo["collectives"]["counts"].items() if v}
            coll_kinds = "hlo_ops=" + "+".join(f"{k}:{v}" for k, v in kinds.items())
        out.append((f"{name}.collective_s", r["collective_s"], coll_kinds))
        out.append((f"{name}.model_flop_ratio", useful, "6ND (or 2ND) / analytic"))
    return out


def summary_table() -> str:
    """Markdown table for EXPERIMENTS.md §Roofline (single pod)."""
    lines = ["| arch | shape | compute_s | memory_s | collective_s | dominant"
             " | MODEL/EST flops | HLO collectives (counts) |",
             "|---|---|---|---|---|---|---|---|"]
    for arch, shape_name, r, hlo in full_table():
        if r is None:
            lines.append(f"| {arch} | {shape_name} | — | — | — | skip | — | "
                         "enc-dec audio (DESIGN.md) |")
            continue
        cfg, shape = get_config(arch), get_shape(shape_name)
        useful = model_flops(cfg, shape) / max(r["flops"], 1.0)
        kinds = "-"
        if hlo:
            nonzero = {k: v for k, v in hlo["collectives"]["counts"].items() if v}
            kinds = " ".join(f"{k.replace('all-','a')}:{v}" for k, v in nonzero.items()) or "-"
        lines.append(
            f"| {arch} | {shape_name} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"{r['dominant'].replace('_s', '')} | {useful:.2f} | {kinds} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(summary_table())
