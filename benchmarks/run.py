"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Figure benchmarks train a small
synthetic-data AgileNN system once (cached) and reuse it; the roofline
table reads the dry-run JSON dumps if present.

  PYTHONPATH=src python -m benchmarks.run                 # everything
  PYTHONPATH=src python -m benchmarks.run --only fig16,tab2
  PYTHONPATH=src python -m benchmarks.run --only kernels,serve \
      --json BENCH_kernels.json                           # perf baseline
  PYTHONPATH=src python -m benchmarks.run --json B.json --smoke   # CI
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated figure keys (fig16..fig24, tab2, "
                         "kernels, serve, serve_sharded, gateway, faults, "
                         "prefix, stream, recovery, telemetry, roofline)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump the collected rows as a JSON baseline")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: cheap suites only (kernels, serve, "
                         "gateway, faults, prefix, stream, recovery, "
                         "telemetry) with shrunk workloads")
    ap.add_argument("--compare", default=None, metavar="BASELINE",
                    help="regression gate: compare collected rows against a "
                         "JSON baseline and exit 2 if any matching row "
                         "regresses by more than 25%% (machine-speed "
                         "normalized; rows whose derived string differs are "
                         "skipped as incomparable workloads)")
    args = ap.parse_args(argv)

    import benchmarks.common
    if args.smoke:
        benchmarks.common.SMOKE = True

    from benchmarks.ablations import ABLATIONS
    from benchmarks.faults import faults_rows
    from benchmarks.gateway import gateway_rows
    from benchmarks.kernel_micro import kernel_micro_rows
    from benchmarks.paper_figures import ALL_FIGURES
    from benchmarks.prefix_cache import prefix_cache_rows
    from benchmarks.recovery import recovery_rows
    from benchmarks.roofline_table import roofline_rows
    from benchmarks.serve_sharded import serve_sharded_rows
    from benchmarks.serve_steady import serve_steady_rows
    from benchmarks.stream_slo import stream_slo_rows
    from benchmarks.telemetry_bench import telemetry_rows

    suites = dict(ALL_FIGURES)
    suites.update(ABLATIONS)
    suites["kernels"] = kernel_micro_rows
    suites["serve"] = serve_steady_rows
    suites["serve_sharded"] = serve_sharded_rows
    suites["gateway"] = gateway_rows
    suites["faults"] = faults_rows
    suites["prefix"] = prefix_cache_rows
    suites["stream"] = stream_slo_rows
    suites["recovery"] = recovery_rows
    suites["telemetry"] = telemetry_rows
    suites["roofline"] = roofline_rows

    if args.only:
        selected = args.only.split(",")
    elif args.smoke:
        # serve_sharded is not in the default smoke set: its rows pin the
        # device topology, and only the multi-device CI job (forced
        # 8-device mesh, --only serve_sharded) has baseline rows to match
        selected = ["kernels", "serve", "gateway", "faults", "prefix",
                    "stream", "recovery", "telemetry"]
    else:
        selected = list(suites)
    print("name,value,derived")
    failed = 0
    collected = []
    for key in selected:
        if key not in suites:
            failed += 1
            print(f"{key},ERROR,unknown suite", flush=True)
            continue
        try:
            for name, value, derived in suites[key]():
                collected.append({"name": name, "value": value,
                                  "derived": derived})
                if isinstance(value, float):
                    value = f"{value:.6g}"
                print(f"{name},{value},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001
            failed += 1
            print(f"{key},ERROR,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc(limit=3, file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"suites": selected, "rows": collected}, f, indent=1)
        print(f"wrote {args.json}", file=sys.stderr)
    if failed:
        sys.exit(1)
    if args.compare and compare_rows(collected, args.compare):
        sys.exit(2)


def compare_rows(collected: list, baseline_path: str) -> list:
    """Gate collected rows against a baseline; returns the regressions.

    A row is comparable when the baseline holds the same name AND the
    same derived string (the derived text pins the workload — a smoke-
    sized serve row must not be judged against the full-queue baseline).
    Lower-is-better rows (us / ms suffixes) regress when they grow >25%
    over baseline; throughput rows (tokens_per_s) when they shrink >25%.
    Wall-clock ratios are normalized by their median baseline/current
    speed ratio so a uniformly slower CI box doesn't trip the gate —
    only a row that regresses relative to the rest of the fleet does.
    Rows whose derived string ends in "simulated" are deterministic
    model outputs (the gateway's seeded fleet): machine speed cannot
    move them, so they are excluded from the median and gated
    symmetrically on their raw ratio — a >25% drift in EITHER direction
    is a semantic change to the simulation (an intentional one ships a
    regenerated baseline).  Rows ending in "_pct" are *already* ratios
    (telemetry overhead as a percentage of the uninstrumented drain):
    machine speed cancels out of them, so instead of baseline-ratio math
    they are gated against an absolute ceiling — >= 5% fails outright.
    """
    with open(baseline_path) as f:
        base = {r["name"]: r for r in json.load(f)["rows"]}
    pairs = []
    pct_fails = []
    for row in collected:
        b = base.get(row["name"])
        if (b is None or b.get("derived") != row["derived"]
                or not isinstance(row["value"], (int, float))
                or not isinstance(b["value"], (int, float))):
            continue
        name = row["name"]
        if name.endswith("_pct"):
            if row["value"] >= 5.0:
                pct_fails.append((name, float(row["value"])))
            continue
        if not b["value"] or not row["value"]:
            continue
        lower_better = name.endswith(".us") or name.endswith("_ms") \
            or name.endswith(".ms")
        higher_better = "per_s" in name
        deterministic = str(row["derived"]).endswith("simulated")
        if deterministic:
            # any drift is semantic: direction doesn't matter
            ratio = max(row["value"] / b["value"],
                        b["value"] / row["value"])
        elif lower_better or higher_better:
            # slowdown ratio > 1 means this row got slower than baseline
            ratio = (row["value"] / b["value"] if lower_better
                     else b["value"] / row["value"])
        else:
            continue
        pairs.append((name, ratio, deterministic))
    for n, v in pct_fails:
        print(f"REGRESSION {n}: {v:.2f}% >= 5% absolute ceiling",
              file=sys.stderr)
    if not pairs:
        if not pct_fails:
            print(f"compare: no comparable rows in {baseline_path}",
                  file=sys.stderr)
        return pct_fails
    walls = sorted(r for _, r, det in pairs if not det) \
        or sorted(r for _, r, _ in pairs)
    mid = len(walls) // 2                          # machine-speed median:
    scale = (walls[mid] if len(walls) % 2          # a true median, so an
             else (walls[mid - 1] + walls[mid]) / 2)    # even-count list
    # can't adopt an upper-middle regression as the machine speed
    # wall-clock rows must fail both tests: the raw ratio (the row
    # actually got slower) and the normalized one (slower than the fleet
    # explains) — a row whose absolute time never grew is not a
    # regression just because the CI box runs its neighbours faster.
    # deterministic rows fail on raw ratio alone.
    regressions = [(n, r, r if det else r / scale) for n, r, det in pairs
                   if r > 1.25 and (det or r / scale > 1.25)]
    for n, raw, rel in regressions:
        print(f"REGRESSION {n}: {raw:.2f}x slower than baseline "
              f"({rel:.2f}x after machine normalization)", file=sys.stderr)
    if not regressions and not pct_fails:
        print(f"compare: {len(pairs)} rows within 25% of {baseline_path} "
              f"(median speed ratio {scale:.2f})", file=sys.stderr)
    return regressions + pct_fails


if __name__ == "__main__":
    main()
