"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Figure benchmarks train a small
synthetic-data AgileNN system once (cached) and reuse it; the roofline
table reads the dry-run JSON dumps if present.

  PYTHONPATH=src python -m benchmarks.run                 # everything
  PYTHONPATH=src python -m benchmarks.run --only fig16,tab2
  PYTHONPATH=src python -m benchmarks.run --only kernels,serve \
      --json BENCH_kernels.json                           # perf baseline
  PYTHONPATH=src python -m benchmarks.run --json B.json --smoke   # CI
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated figure keys (fig16..fig24, tab2, "
                         "kernels, serve, roofline)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump the collected rows as a JSON baseline")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: cheap suites only (kernels, serve) "
                         "with shrunk workloads")
    args = ap.parse_args(argv)

    import benchmarks.common
    if args.smoke:
        benchmarks.common.SMOKE = True

    from benchmarks.ablations import ABLATIONS
    from benchmarks.kernel_micro import kernel_micro_rows
    from benchmarks.paper_figures import ALL_FIGURES
    from benchmarks.roofline_table import roofline_rows
    from benchmarks.serve_steady import serve_steady_rows

    suites = dict(ALL_FIGURES)
    suites.update(ABLATIONS)
    suites["kernels"] = kernel_micro_rows
    suites["serve"] = serve_steady_rows
    suites["roofline"] = roofline_rows

    if args.only:
        selected = args.only.split(",")
    elif args.smoke:
        selected = ["kernels", "serve"]
    else:
        selected = list(suites)
    print("name,value,derived")
    failed = 0
    collected = []
    for key in selected:
        if key not in suites:
            failed += 1
            print(f"{key},ERROR,unknown suite", flush=True)
            continue
        try:
            for name, value, derived in suites[key]():
                collected.append({"name": name, "value": value,
                                  "derived": derived})
                if isinstance(value, float):
                    value = f"{value:.6g}"
                print(f"{name},{value},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001
            failed += 1
            print(f"{key},ERROR,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc(limit=3, file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"suites": selected, "rows": collected}, f, indent=1)
        print(f"wrote {args.json}", file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
