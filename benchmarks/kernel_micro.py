"""Kernel microbenchmarks: us/call of the jnp substrate paths on CPU
(interpret-mode Pallas is a correctness harness, not a perf path, so the
timed paths are the jit'd jnp implementations the dry-run lowers)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import timed_us
from repro.nn.attention import flash_attention
from repro.nn.moe import moe_apply, moe_init
from repro.nn.ssm import mamba_apply, mamba_init
from repro.nn.xlstm import mlstm_apply, mlstm_init

KEY = jax.random.PRNGKey(0)


def kernel_micro_rows() -> list[tuple]:
    rows = []
    B, T, H, D = 1, 512, 4, 64
    q = jax.random.normal(KEY, (B, T, H, D))
    k = jax.random.normal(KEY, (B, T, 2, D))
    v = jax.random.normal(KEY, (B, T, 2, D))
    f = jax.jit(lambda q, k, v: flash_attention(q, k, v, q_block=128,
                                                kv_block=128))
    us = timed_us(f, q, k, v)
    flops = 4 * B * T * T * H * D / 2  # causal
    rows.append(("kernel.flash_attention.us", us,
                 f"gflops={flops / us / 1e3:.2f}"))

    p = moe_init(KEY, 128, 256, 8)
    x = jax.random.normal(KEY, (2, 256, 128))
    f = jax.jit(lambda p, x: moe_apply(p, x, top_k=2)[0])
    rows.append(("kernel.moe_dispatch.us", timed_us(f, p, x), "8e top-2"))

    p = mamba_init(KEY, 128)
    x = jax.random.normal(KEY, (1, 512, 128))
    f = jax.jit(lambda p, x: mamba_apply(p, x, chunk=128))
    rows.append(("kernel.mamba_scan.us", timed_us(f, p, x), "chunked"))

    p = mlstm_init(KEY, 128, 4)
    f = jax.jit(lambda p, x: mlstm_apply(p, x, n_heads=4, chunk=64))
    rows.append(("kernel.mlstm_chunked.us", timed_us(f, p, x), ""))
    return rows
