"""Kernel microbenchmarks: us/call of the jnp substrate paths on CPU
(interpret-mode Pallas is a correctness harness, not a perf path, so the
timed paths are the jit'd jnp implementations the dry-run lowers)."""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp

import numpy as np

from benchmarks.common import timed_us
from repro.nn.attention import flash_attention
from repro.nn.moe import moe_apply, moe_init
from repro.nn.ssm import mamba_apply, mamba_init
from repro.nn.xlstm import mlstm_apply, mlstm_init

KEY = jax.random.PRNGKey(0)


def offload_hot_path_rows() -> list[tuple]:
    """Online offload hot path: fused one-pass split+quantize vs the seed
    two-pass composition, vectorized vs per-sample bit-packing, and one
    serving-engine decode step."""
    from functools import partial

    from repro.compress.lzw import pack_indices, pack_indices_batch
    from repro.compress.quantize import dequantize, hard_indices
    from repro.kernels.offload_fused.ops import fused_offload_jnp

    rows = []
    B, H, W, C, k, L = 64, 8, 8, 64, 8, 8
    x = jax.random.normal(KEY, (B, H, W, C))
    centers = jnp.linspace(-3, 3, L)
    perm = tuple(int(i) for i in np.random.RandomState(0).permutation(C))
    q = {"centers": centers}

    @jax.jit
    def seed_two_pass(x, centers):
        y = jnp.take(x, jnp.asarray(perm), axis=-1)
        f_local, f_remote = y[..., :k], y[..., k:]
        idx = hard_indices({"centers": centers}, f_remote)
        return f_local, idx, dequantize({"centers": centers}, idx)

    fused = jax.jit(partial(fused_offload_jnp, perm=perm, k=k))
    us_seed = timed_us(seed_two_pass, x, centers, iters=20)
    us_fused = timed_us(fused, x, centers, iters=20)
    rows.append(("kernel.offload_split_quant_seed.us", us_seed,
                 f"B{B}x{H}x{W}x{C} 2-pass"))
    rows.append(("kernel.offload_split_quant_fused.us", us_fused,
                 f"B{B}x{H}x{W}x{C} fused 1-pass"))

    # serving-shaped packing: many independent samples, small payload each
    Bp = 256
    idx = np.asarray(hard_indices(q, jax.random.normal(KEY, (Bp, 4, 4, C - k))))
    bits = 3

    def pack_loop(idx):
        return [pack_indices(idx[b], bits) for b in range(idx.shape[0])]

    us_loop = timed_us(pack_loop, idx, iters=20)
    us_vec = timed_us(lambda a: pack_indices_batch(a, bits), idx, iters=20)
    rows.append(("kernel.pack_indices_loop.us", us_loop, f"B={Bp} per-sample"))
    rows.append(("kernel.pack_indices_batch.us", us_vec,
                 f"B={Bp} vectorized"))

    from repro.configs import get_config
    from repro.models import backbone as bb
    cfg = get_config("qwen2-0.5b").reduced()
    params = bb.init_params(cfg, KEY)
    batch = {"tokens": jnp.zeros((2, 8), jnp.int32)}
    logits, cache, total_T = bb.prefill(cfg, params, batch, max_len=64)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    step = jax.jit(lambda p, t, c, n: bb.decode_step(cfg, p, t, c, n))
    us_step = timed_us(lambda p, t, c: step(p, t, c, total_T)[0],
                       params, tok, cache, iters=10)
    rows.append(("engine.decode_step.us", us_step, "qwen2-0.5b reduced B=2"))
    return rows


def decode_attention_rows() -> list[tuple]:
    """Serving decode attention: the seed dense einsum over the full
    cache width vs the paged path that visits only the KV pages below
    the pool's deepest live row (slot pools mostly sit far below
    capacity, here depths <= S/4)."""
    from functools import partial

    from repro.kernels.decode_attention.ops import paged_decode_attention_jnp
    from repro.kernels.decode_attention.ref import decode_attention_ref

    B, S, Hq, Hkv, D = 8, 1024, 8, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, 1, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    attend = jnp.asarray(np.linspace(32, 256, B).astype(np.int32))

    dense = jax.jit(decode_attention_ref)
    paged = jax.jit(partial(paged_decode_attention_jnp, page_size=128))
    us_seed = timed_us(dense, q, k, v, attend, iters=50)
    us_paged = timed_us(paged, q, k, v, attend, iters=50)
    # NOTE: derived strings stay measurement-free — the --compare gate
    # only judges rows whose name AND derived match the baseline, so a
    # re-measured ratio in derived would exempt the row from gating
    print(f"decode_attention paged speedup: {us_seed / us_paged:.2f}x",
          file=sys.stderr)
    return [
        ("kernel.decode_attention_seed.us", us_seed,
         f"B{B} S{S} Hq{Hq} dense full-width"),
        ("kernel.decode_attention_paged.us", us_paged,
         f"B{B} S{S} Hq{Hq} depths<=256 page128"),
    ]


def kernel_micro_rows() -> list[tuple]:
    rows = []
    B, T, H, D = 1, 512, 4, 64
    q = jax.random.normal(KEY, (B, T, H, D))
    k = jax.random.normal(KEY, (B, T, 2, D))
    v = jax.random.normal(KEY, (B, T, 2, D))
    f = jax.jit(lambda q, k, v: flash_attention(q, k, v, q_block=128,
                                                kv_block=128))
    us = timed_us(f, q, k, v)
    rows.append(("kernel.flash_attention.us", us,
                 f"B{B} T{T} H{H} D{D} causal"))

    p = moe_init(KEY, 128, 256, 8)
    x = jax.random.normal(KEY, (2, 256, 128))
    f = jax.jit(lambda p, x: moe_apply(p, x, top_k=2)[0])
    rows.append(("kernel.moe_dispatch.us", timed_us(f, p, x), "8e top-2"))

    p = mamba_init(KEY, 128)
    x = jax.random.normal(KEY, (1, 512, 128))
    f = jax.jit(lambda p, x: mamba_apply(p, x, chunk=128))
    rows.append(("kernel.mamba_scan.us", timed_us(f, p, x), "chunked"))

    p = mlstm_init(KEY, 128, 4)
    f = jax.jit(lambda p, x: mlstm_apply(p, x, n_heads=4, chunk=64))
    rows.append(("kernel.mlstm_chunked.us", timed_us(f, p, x), ""))
    rows.extend(decode_attention_rows())
    rows.extend(offload_hot_path_rows())
    return rows
