"""Kernel microbenchmarks: us/call of the jnp substrate paths on CPU
(interpret-mode Pallas is a correctness harness, not a perf path, so the
timed paths are the jit'd jnp implementations the dry-run lowers)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

import numpy as np

from benchmarks.common import timed_us
from repro.nn.attention import flash_attention
from repro.nn.moe import moe_apply, moe_init
from repro.nn.ssm import mamba_apply, mamba_init
from repro.nn.xlstm import mlstm_apply, mlstm_init

KEY = jax.random.PRNGKey(0)


def offload_hot_path_rows() -> list[tuple]:
    """Online offload hot path: fused one-pass split+quantize vs the seed
    two-pass composition, vectorized vs per-sample bit-packing, and one
    serving-engine decode step."""
    from functools import partial

    from repro.compress.lzw import pack_indices, pack_indices_batch
    from repro.compress.quantize import dequantize, hard_indices
    from repro.kernels.offload_fused.ops import fused_offload_jnp

    rows = []
    B, H, W, C, k, L = 64, 8, 8, 64, 8, 8
    x = jax.random.normal(KEY, (B, H, W, C))
    centers = jnp.linspace(-3, 3, L)
    perm = tuple(int(i) for i in np.random.RandomState(0).permutation(C))
    q = {"centers": centers}

    @jax.jit
    def seed_two_pass(x, centers):
        y = jnp.take(x, jnp.asarray(perm), axis=-1)
        f_local, f_remote = y[..., :k], y[..., k:]
        idx = hard_indices({"centers": centers}, f_remote)
        return f_local, idx, dequantize({"centers": centers}, idx)

    fused = jax.jit(partial(fused_offload_jnp, perm=perm, k=k))
    us_seed = timed_us(seed_two_pass, x, centers, iters=20)
    us_fused = timed_us(fused, x, centers, iters=20)
    rows.append(("kernel.offload_split_quant_seed.us", us_seed,
                 f"B{B}x{H}x{W}x{C} 2-pass"))
    rows.append(("kernel.offload_split_quant_fused.us", us_fused,
                 f"speedup={us_seed / us_fused:.2f}x"))

    # serving-shaped packing: many independent samples, small payload each
    Bp = 256
    idx = np.asarray(hard_indices(q, jax.random.normal(KEY, (Bp, 4, 4, C - k))))
    bits = 3

    def pack_loop(idx):
        return [pack_indices(idx[b], bits) for b in range(idx.shape[0])]

    us_loop = timed_us(pack_loop, idx, iters=20)
    us_vec = timed_us(lambda a: pack_indices_batch(a, bits), idx, iters=20)
    rows.append(("kernel.pack_indices_loop.us", us_loop, f"B={Bp} per-sample"))
    rows.append(("kernel.pack_indices_batch.us", us_vec,
                 f"speedup={us_loop / us_vec:.2f}x"))

    from repro.configs import get_config
    from repro.models import backbone as bb
    cfg = get_config("qwen2-0.5b").reduced()
    params = bb.init_params(cfg, KEY)
    batch = {"tokens": jnp.zeros((2, 8), jnp.int32)}
    logits, cache, total_T = bb.prefill(cfg, params, batch, max_len=64)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    step = jax.jit(lambda p, t, c, n: bb.decode_step(cfg, p, t, c, n))
    us_step = timed_us(lambda p, t, c: step(p, t, c, total_T)[0],
                       params, tok, cache, iters=10)
    rows.append(("engine.decode_step.us", us_step, "qwen2-0.5b reduced B=2"))
    return rows


def kernel_micro_rows() -> list[tuple]:
    rows = []
    B, T, H, D = 1, 512, 4, 64
    q = jax.random.normal(KEY, (B, T, H, D))
    k = jax.random.normal(KEY, (B, T, 2, D))
    v = jax.random.normal(KEY, (B, T, 2, D))
    f = jax.jit(lambda q, k, v: flash_attention(q, k, v, q_block=128,
                                                kv_block=128))
    us = timed_us(f, q, k, v)
    flops = 4 * B * T * T * H * D / 2  # causal
    rows.append(("kernel.flash_attention.us", us,
                 f"gflops={flops / us / 1e3:.2f}"))

    p = moe_init(KEY, 128, 256, 8)
    x = jax.random.normal(KEY, (2, 256, 128))
    f = jax.jit(lambda p, x: moe_apply(p, x, top_k=2)[0])
    rows.append(("kernel.moe_dispatch.us", timed_us(f, p, x), "8e top-2"))

    p = mamba_init(KEY, 128)
    x = jax.random.normal(KEY, (1, 512, 128))
    f = jax.jit(lambda p, x: mamba_apply(p, x, chunk=128))
    rows.append(("kernel.mamba_scan.us", timed_us(f, p, x), "chunked"))

    p = mlstm_init(KEY, 128, 4)
    f = jax.jit(lambda p, x: mlstm_apply(p, x, n_heads=4, chunk=64))
    rows.append(("kernel.mlstm_chunked.us", timed_us(f, p, x), ""))
    rows.extend(offload_hot_path_rows())
    return rows
