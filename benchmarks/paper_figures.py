"""One benchmark per paper table/figure (§7), run against the trained
synthetic-data system.  Each function returns a list of CSV rows
(name, value, derived)."""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import eval_accuracy, trained_baselines, trained_system
from repro.core.agile import agile_predict
from repro.core.baselines import (
    deepcod_cost,
    deepcod_forward,
    deepcod_payload,
    edge_only_cost,
    mcunet_apply,
    mcunet_cost,
    spinn_cost,
    spinn_forward,
)
from repro.serve.device_model import DeviceModel
from repro.serve.offload import (
    energy_per_inference,
    measure_payload,
    remote_nn_macs,
    run_offload_inference,
)


def _device(cfg, **kw):
    return DeviceModel(cpu_hz=cfg.mcu_hz, link_bps=cfg.link_bps, **kw)


# ------------------------------------------------- Figure 16: latency ------
def fig16_latency_accuracy() -> list[tuple]:
    cfg, params, ref, report, data = trained_system()
    baselines = trained_baselines()
    images, labels = data.batch(64, seed=990_000)
    rows = []

    preds, cost = run_offload_inference(cfg, params, images)
    acc = eval_accuracy(lambda im: jnp.argmax(agile_predict(cfg, params, im)[0], -1), data)
    rows.append(("fig16.agilenn.latency_ms", cost.end_to_end_s * 1e3, f"acc={acc:.3f}"))
    rows.append(("fig16.agilenn.local_ms", cost.local_compute_s * 1e3, ""))

    rmacs = remote_nn_macs(cfg, cfg.image_size // 4)
    dp, _ = baselines["deepcod"]
    dcost = deepcod_cost(cfg, dp, images, remote_macs=rmacs)
    dacc = eval_accuracy(lambda im: jnp.argmax(deepcod_forward(dp, im, train=False)[0], -1), data)
    rows.append(("fig16.deepcod.latency_ms", dcost.end_to_end_s * 1e3, f"acc={dacc:.3f}"))

    sp, _ = baselines["spinn"]
    scost = spinn_cost(cfg, sp, images, remote_macs=rmacs)
    sacc = eval_accuracy(lambda im: jnp.argmax(spinn_forward(sp, im, train=False)[1], -1), data)
    rows.append(("fig16.spinn.latency_ms", scost.end_to_end_s * 1e3, f"acc={sacc:.3f}"))

    mc, _ = baselines["mcunet"]
    mcost = mcunet_cost(cfg)
    macc = eval_accuracy(lambda im: jnp.argmax(mcunet_apply(mc, im), -1), data)
    rows.append(("fig16.mcunet.latency_ms", mcost.end_to_end_s * 1e3, f"acc={macc:.3f}"))

    ecost = edge_only_cost(cfg, np.asarray(images), remote_macs=rmacs)
    rows.append(("fig16.edge_only.latency_ms", ecost.end_to_end_s * 1e3,
                 f"acc={report['reference_accuracy']:.3f}"))
    agile_vs_mcunet = mcost.end_to_end_s / max(cost.end_to_end_s, 1e-9)
    rows.append(("fig16.speedup_vs_mcunet", agile_vs_mcunet, "paper: up to 6x"))
    return rows


# --------------------------------------------- Table 2: transmission -------
def tab2_transmission() -> list[tuple]:
    cfg, params, ref, _, data = trained_system()
    dp, _ = trained_baselines()["deepcod"]
    images, _ = data.batch(64, seed=990_001)
    agile_bytes, _ = measure_payload(cfg, params, images)
    deepcod_bytes = deepcod_payload(dp, images)
    reduction = 1.0 - agile_bytes / max(deepcod_bytes, 1)
    return [("tab2.agilenn.payload_bytes", agile_bytes / 64, ""),
            ("tab2.deepcod.payload_bytes", deepcod_bytes / 64, ""),
            ("tab2.reduction_vs_deepcod", reduction,
             "paper: 15.8%-72.3% across datasets")]


# ------------------------------------- Figure 17: compression rates --------
def fig17_compression_sweep() -> list[tuple]:
    """Vary quantizer resolution (bits/feature) — higher compression =
    fewer centers — and measure accuracy (hard-quantized eval path)."""
    cfg, params, ref, _, data = trained_system()
    from repro.compress.quantize import quantizer_init
    rows = []
    for L in (16, 8, 4, 2):
        p2 = dict(params)
        p2["quant"] = quantizer_init(L, -4, 4)
        acc = eval_accuracy(
            lambda im: jnp.argmax(agile_predict(cfg, p2, im)[0], -1), data)
        images, _ = data.batch(64, seed=990_002)
        payload, _ = measure_payload(cfg, p2, images)
        rows.append((f"fig17.agilenn.acc@{L}centers", acc,
                     f"payload={payload / 64:.0f}B"))
    return rows


# ---------------------------------------- Figure 18: alpha reweighting -----
def fig18_alpha_sweep() -> list[tuple]:
    cfg, params, ref, _, data = trained_system()
    rows = []
    for a in (0.0, 0.15, 0.3, 0.45, 0.6, 0.8, 1.0):
        acc = eval_accuracy(
            lambda im: jnp.argmax(
                agile_predict(cfg, params, im, alpha_override=a)[0], -1), data)
        rows.append((f"fig18.acc@alpha={a}", acc, ""))
    return rows


# ------------------------------------- Figure 21: skewness settings --------
def fig21_skewness_grid() -> list[tuple]:
    """k in {10%, 20%, 30%} of channels with rho {0.7, 0.8, 0.9}."""
    rows = []
    for k, rho in ((3, 0.7), (5, 0.8), (7, 0.9)):
        cfg, params, ref, report, data = trained_system(k=k, rho=rho)
        images, _ = data.batch(64, seed=990_003)
        payload, _ = measure_payload(cfg, params, images)
        dev = _device(cfg)
        rows.append((f"fig21.skewness@k{k}rho{rho}", report["skewness"],
                     f"required={rho}"))
        rows.append((f"fig21.accuracy@k{k}rho{rho}", report["accuracy"],
                     f"disorder={report['disorder_rate']:.3f}"))
        rows.append((f"fig21.tx_ms@k{k}rho{rho}",
                     dev.tx_time(payload / 64) * 1e3, ""))
    return rows


# --------------------------------------- Figure 22: CPU frequency ----------
def fig22_cpu_frequency() -> list[tuple]:
    cfg, params, ref, _, data = trained_system()
    mc, _ = trained_baselines()["mcunet"]
    images, _ = data.batch(64, seed=990_004)
    rows = []
    for mhz in (216, 128, 64, 16):
        dev = DeviceModel(cpu_hz=mhz * 1e6, link_bps=cfg.link_bps)
        _, cost = run_offload_inference(cfg, params, images, device=dev)
        mcost = mcunet_cost(cfg, device=dev)
        rows.append((f"fig22.agilenn.latency_ms@{mhz}MHz",
                     cost.end_to_end_s * 1e3, ""))
        rows.append((f"fig22.mcunet.latency_ms@{mhz}MHz",
                     mcost.end_to_end_s * 1e3, ""))
    return rows


# --------------------------------------- Figure 23: network bandwidth ------
def fig23_bandwidth() -> list[tuple]:
    cfg, params, ref, _, data = trained_system()
    dp, _ = trained_baselines()["deepcod"]
    images, _ = data.batch(64, seed=990_005)
    rmacs = remote_nn_macs(cfg, cfg.image_size // 4)
    rows = []
    for bps in (6e6, 1e6, 270e3):
        dev = DeviceModel(cpu_hz=cfg.mcu_hz, link_bps=bps)
        _, cost = run_offload_inference(cfg, params, images, device=dev)
        dcost = deepcod_cost(cfg, dp, images, remote_macs=rmacs, device=dev)
        label = f"{bps/1e6:.2f}Mbps" if bps >= 1e6 else f"{bps/1e3:.0f}kbps"
        rows.append((f"fig23.agilenn.latency_ms@{label}",
                     cost.end_to_end_s * 1e3, "paper: <=100ms @270kbps"))
        rows.append((f"fig23.deepcod.latency_ms@{label}",
                     dcost.end_to_end_s * 1e3, ""))
    return rows


# --------------------------------------- Figure 24: XAI tool choice --------
def fig24_xai_choice() -> list[tuple]:
    rows = []
    for method in ("ig", "saliency"):
        cfg, params, ref, report, data = trained_system(xai_method=method)
        rows.append((f"fig24.accuracy@{method}", report["accuracy"],
                     f"skew={report['skewness']:.3f}"))
        rows.append((f"fig24.train_wall_s@{method}", report["train_wall_s"],
                     "IG costs ig_steps gradient passes per eval"))
    return rows


# ------------------------------------------ Figure 19: energy --------------
def fig19_energy() -> list[tuple]:
    cfg, params, ref, _, data = trained_system()
    baselines = trained_baselines()
    images, _ = data.batch(64, seed=990_006)
    dev = _device(cfg)
    _, cost = run_offload_inference(cfg, params, images)
    agile_mj = energy_per_inference(cfg, cost) * 1e3
    mcost = mcunet_cost(cfg)
    mcu_mj = dev.energy(mcost.local_macs, 0) * 1e3
    dp, _ = baselines["deepcod"]
    dcost = deepcod_cost(cfg, dp, images,
                         remote_macs=remote_nn_macs(cfg, cfg.image_size // 4))
    dc_mj = dev.energy(dcost.local_macs, dcost.payload_bytes) * 1e3
    return [("fig19.agilenn.energy_mj", agile_mj, ""),
            ("fig19.mcunet.energy_mj", mcu_mj,
             f"ratio={mcu_mj / max(agile_mj, 1e-9):.1f}x (paper: >8x)"),
            ("fig19.deepcod.energy_mj", dc_mj,
             f"ratio={dc_mj / max(agile_mj, 1e-9):.1f}x (paper: >=2.5x)")]


# ------------------------------------------ Figure 20: memory/storage ------
def fig20_memory() -> list[tuple]:
    from repro.nn.module import param_count
    from repro.serve.device_model import mcu_memory_model
    cfg, params, ref, _, data = trained_system()
    baselines = trained_baselines()
    feat_hw = cfg.image_size // 4
    local_params = (param_count(params["extractor"]) + param_count(params["local"]))
    act = cfg.image_size * cfg.image_size * 3 + feat_hw ** 2 * cfg.extractor_channels
    agile_mem = mcu_memory_model(local_params, act)
    mc, _ = baselines["mcunet"]
    mc_mem = mcu_memory_model(param_count(mc), act * 4)
    return [("fig20.agilenn.flash_kb", agile_mem["flash_bytes"] / 1024, ""),
            ("fig20.agilenn.sram_kb", agile_mem["sram_bytes"] / 1024,
             "STM32F746: 320KB SRAM / 1MB flash"),
            ("fig20.mcunet.flash_kb", mc_mem["flash_bytes"] / 1024,
             f"ratio={mc_mem['flash_bytes'] / max(agile_mem['flash_bytes'], 1):.1f}x (paper: ~5x)")]


ALL_FIGURES = {
    "fig16": fig16_latency_accuracy,
    "tab2": tab2_transmission,
    "fig17": fig17_compression_sweep,
    "fig18": fig18_alpha_sweep,
    "fig19": fig19_energy,
    "fig20": fig20_memory,
    "fig21": fig21_skewness_grid,
    "fig22": fig22_cpu_frequency,
    "fig23": fig23_bandwidth,
    "fig24": fig24_xai_choice,
}
