"""Mesh-sharded slot-pool serving benchmark: overlap on vs off.

The same Poisson mixed-length queue as `serve_steady` runs through a
scheduler whose slot pool is sharded over the data axis of a serving
mesh (all visible devices; on the forced 8-device CPU mesh of the
multi-device CI step this is a real 8-way shard, on a laptop it is the
degenerate (1, 1) mesh — the code path is identical either way).  A
long-prompt stream exercises chunked prefill so the overlapped pipeline
has prefill segments to hide behind decode chunks.

Two rows, identical workloads: ``serve.sharded_tokens_per_s`` is the
overlapped pipeline, ``serve.sharded_serialized_tokens_per_s`` the
serialized rounds — the gap is what async dispatch + double-buffered
admission buys.  Both derived strings record the device count and mesh
shape, so ``--compare`` only ever matches rows from the same topology.
"""
from __future__ import annotations

import jax
import numpy as np

import benchmarks.common as common

KEY = jax.random.PRNGKey(0)


def serve_sharded_rows() -> list[tuple]:
    from repro.configs import get_config
    from repro.launch.mesh import make_serving_mesh
    from repro.models import backbone as bb
    from repro.serve.engine import Request
    from repro.serve.scheduler import ContinuousScheduler, SchedulerConfig
    from benchmarks.serve_steady import _drain_with_poisson_arrivals

    smoke = getattr(common, "SMOKE", False)
    n_requests = 10 if smoke else 24
    max_new = 6 if smoke else 16
    lengths = (8, 16, 32, 100, 128)      # long tail -> chunked prefill

    # the 8-slot pool must divide the data axis: largest divisor <= the
    # visible device count (8 on the forced-count CI mesh, 1 locally)
    data = max(d for d in (8, 4, 2, 1) if d <= jax.device_count())
    mesh = make_serving_mesh(data=data, model=1)
    topo = f"devices={jax.device_count()} mesh=({data},1)"

    cfg = get_config("qwen2-0.5b").reduced()
    params = bb.init_params(cfg, KEY)
    rng = np.random.RandomState(0)
    reqs = [Request(tokens=rng.randint(0, cfg.vocab, rng.choice(lengths)),
                    max_new_tokens=max_new) for _ in range(n_requests)]

    def build(overlap: bool) -> ContinuousScheduler:
        sched = ContinuousScheduler(
            cfg, params, max_len=max(lengths) + max_new + 8, mesh=mesh,
            sched=SchedulerConfig(buckets=lengths, max_slots=8,
                                  prefill_group=4, chunk=4,
                                  prefill_segment=32, overlap=overlap))
        # warm-up drain pays the per-bucket prefill + segment + chunk
        # compiles (shared jit caches make the second build cheap)
        _drain_with_poisson_arrivals(sched, reqs, np.random.RandomState(1),
                                     rate=3.0)
        return sched

    # paired min-of-3: the two modes' timed drains alternate so a load
    # spike on a shared CI box hits both rows, not just one — the
    # overlap-vs-serialized comparison stays meaningful under noise
    scheds = {True: build(True), False: build(False)}
    best = common.paired_best_of(
        {overlap: (lambda s=sched: _drain_with_poisson_arrivals(
            s, reqs, np.random.RandomState(1), rate=3.0))
         for overlap, sched in scheds.items()}, 3)

    pin = f"{n_requests} reqs Poisson mix {lengths} max_new={max_new}"
    return [
        ("serve.sharded_tokens_per_s", n_requests * max_new / best[True],
         f"{pin} overlap=on {topo}"),
        ("serve.sharded_serialized_tokens_per_s",
         n_requests * max_new / best[False], f"{pin} overlap=off {topo}"),
    ]
