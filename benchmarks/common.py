"""Shared benchmark fixtures: one trained AgileNN system + trained
baselines, reused by every per-figure benchmark."""
from __future__ import annotations

import time
from functools import lru_cache

import jax
import numpy as np

from repro.configs.agilenn_cifar import AgileNNConfig
from repro.configs.base import AgileSpec

# set by benchmarks.run --smoke: suites shrink their workloads (CI-sized)
SMOKE = False

QUICK_CFG = AgileNNConfig(image_size=16, remote_width=24, remote_blocks=2,
                          reference_width=32, reference_blocks=3,
                          agile=AgileSpec(enabled=True, extractor_channels=24,
                                          k=5, rho=0.8, lam=0.3, ig_steps=4))


@lru_cache(maxsize=None)
def trained_system(xai_method: str = "ig", k: int = 5, rho: float = 0.8,
                   joint_steps: int = 150, pretrain_steps: int = 60):
    """Train (cached) and return (cfg, params, ref_params, report, data)."""
    import dataclasses
    from repro.train.agile_pipeline import run_full_pipeline
    cfg = dataclasses.replace(
        QUICK_CFG, agile=dataclasses.replace(QUICK_CFG.agile, k=k, rho=rho))
    t0 = time.time()
    params, ref, report, hist, data = run_full_pipeline(
        cfg, pretrain_steps=pretrain_steps, joint_steps=joint_steps,
        batch_size=32, xai_method=xai_method)
    report["train_wall_s"] = round(time.time() - t0, 1)
    return cfg, params, ref, report, data


@lru_cache(maxsize=None)
def trained_baselines(steps: int = 150):
    """DeepCOD + SPINN + MCUNet-proxy trained on the same data."""
    from repro.core.baselines import (
        deepcod_init, deepcod_loss, mcunet_apply, mcunet_init, spinn_init,
        spinn_loss, train_baseline)
    from repro.core.agile import cross_entropy
    import jax.numpy as jnp
    cfg, _, _, _, data = trained_system()
    key = jax.random.PRNGKey(11)
    k1, k2, k3 = jax.random.split(key, 3)

    deepcod, dc_m = train_baseline(deepcod_loss, deepcod_init(k1, cfg), data,
                                   steps=steps)
    spinn, sp_m = train_baseline(spinn_loss, spinn_init(k2, cfg), data,
                                 steps=steps)

    def mcunet_loss(p, images, labels):
        logits = mcunet_apply(p, images)
        acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
        return cross_entropy(logits, labels), {"accuracy": acc}

    mcunet, mc_m = train_baseline(mcunet_loss, mcunet_init(k3, cfg), data,
                                  steps=steps)
    return {"deepcod": (deepcod, dc_m), "spinn": (spinn, sp_m),
            "mcunet": (mcunet, mc_m)}


def eval_accuracy(predict_fn, data, *, n_batches: int = 3,
                  batch_size: int = 128) -> float:
    accs = []
    for i in range(n_batches):
        images, labels = data.batch(batch_size, seed=880_000 + i)
        preds = np.asarray(predict_fn(images))
        accs.append(float((preds == labels).mean()))
    return float(np.mean(accs))


# ---------------------------------------------------- timing helpers --
# The min-of-N / median-of-N / percentile arithmetic every suite used to
# hand-roll lives here.  The minimum — not the mean — is the timing
# estimator of choice: scheduler preemption and frequency ramps only
# ever *add* time, so min-of-N is the stable estimate of the code's
# actual cost, and the --compare regression gate needs numbers that
# don't wobble with box load.


def best_of(fn, n: int) -> float:
    """Minimum of ``n`` calls of ``fn()`` (min-of-N timing)."""
    return min(fn() for _ in range(n))


def median_of(fn, n: int) -> float:
    """Middle value of ``n`` calls of ``fn()`` — for quantities where a
    cold-start minimum would flatter (cache-hit timings)."""
    vals = sorted(fn() for _ in range(n))
    return vals[n // 2]


def paired_best_of(fns: dict, n: int) -> dict:
    """Min-of-N over several candidates, *alternating within each
    round* so a load spike lands on every candidate of the round and
    the comparison between them stays fair; returns {key: min}."""
    best = {k: float("inf") for k in fns}
    for _ in range(n):
        for k, fn in fns.items():
            best[k] = min(best[k], fn())
    return best


def pctl(values, q: float) -> float:
    """Exact percentile through the telemetry histogram's exact mode —
    bit-identical to ``np.percentile`` on the same samples (the
    closed-form bucketed estimate is for live registries; bench rows
    pin exact values)."""
    from repro.serve.telemetry import Histogram
    h = Histogram.exact()
    for v in np.asarray(values, np.float64).ravel():
        h.observe(float(v))
    return h.percentile(q)


def timed_us(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Best-of-iters call time in microseconds.

    jax.block_until_ready handles arbitrary pytrees (tuples of arrays,
    host-side lists), so async dispatch can't leak out of the timing.
    """
    if SMOKE:            # CI-sized, but still gate-worthy: enough warmup
        # to shake out compilation and enough iters for a clean minimum
        iters, warmup = min(iters, 4), 2

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))

    def once() -> float:
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        return time.perf_counter() - t0

    return best_of(once, iters) * 1e6
