"""Recovery benchmark: crash-replay cost and preemption TTFT, on a
virtual clock.

Scenario A is the pinned crash+stampede chaos run: a 12-request burst
(mixed priority classes, journal attached) loses its engine to a
scripted `EngineCrash` mid-decode; `recover` replays the journal into a
fresh frontend.  The rows are exact outputs of the simulation —
`recovery.replay_ms` is the replay drain's round count times the
modeled ``ROUND_S``, and `recovery.lost_requests` counts journaled
submissions missing from the merged results.  The no-lost-work contract
is *asserted* here (the bench aborts if any request is lost) because
`compare_rows` skips zero-valued rows — the row is kept for visibility,
the assert is the gate.

Scenario B pins preemption's reason to exist: with BEST_EFFORT hogs
holding every slot, INTERACTIVE arrivals land their first token only
after a suspend frees a slot — `stream.preempt_ttft_p99_ms` is that
TTFT on the shared virtual clock.

Both workloads are pinned (no --smoke shrink) so smoke rows stay
comparable to the committed baseline; derived strings end in
"simulated" so `benchmarks.run.compare_rows` gates them symmetrically
on raw ratio.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import pctl

KEY = jax.random.PRNGKey(0)

ROUND_S = 0.01          # modeled service time of one scheduler round
N_BURST = 12            # requests in the crash scenario's stampede
CRASH_ROUND = 8         # scheduler round the engine dies at
N_INTER = 4             # interactive arrivals in the preempt scenario


def _drive(fe, clock):
    while fe.has_work():
        clock.now += ROUND_S
        fe.step()
    out, fe._results = fe._results, {}
    return out


def _crash_replay_rows(cfg, params) -> list[tuple]:
    from repro.serve.engine import Request
    from repro.serve.faults import EngineCrash, EngineCrashError, \
        FaultInjector
    from repro.serve.frontend import (
        FrontendConfig, Priority, StreamingFrontend, VirtualClock)
    from repro.serve.recovery import RequestJournal, recover
    from repro.serve.scheduler import SchedulerConfig

    rng = np.random.RandomState(0)
    reqs = [Request(tokens=rng.randint(0, cfg.vocab,
                                       int(rng.choice((4, 8, 12)))),
                    max_new_tokens=int(4 + rng.randint(0, 4)))
            for _ in range(N_BURST)]
    sched = SchedulerConfig(buckets=(8, 16), max_slots=4,
                            prefill_group=2, chunk=2)
    journal = RequestJournal()
    clock = VirtualClock()
    fe = StreamingFrontend(
        cfg, params, frontend=FrontendConfig(),
        sched=sched, max_len=32, seed=0, clock=clock, journal=journal,
        faults=FaultInjector((EngineCrash(CRASH_ROUND),)))
    for i, r in enumerate(reqs):            # the stampede: one burst
        fe.submit(r, Priority(i % 3))
    try:
        _drive(fe, clock)
        raise AssertionError("scripted crash never fired")
    except EngineCrashError:
        pass

    clock2 = VirtualClock(clock.now)
    fe2 = StreamingFrontend(cfg, params, frontend=FrontendConfig(),
                            sched=sched, max_len=32, seed=0, clock=clock2)
    merged = recover(fe2, journal, drive=lambda: _drive(fe2, clock2))
    submitted = {rec["rid"] for rec in journal.events
                 if rec["ev"] == "submit"}
    lost = len(submitted - set(merged))
    assert lost == 0, f"recovery lost {lost} journaled requests"
    replay_ms = fe2.sched._round * ROUND_S * 1e3
    pin = (f"{N_BURST}-req stampede crash@r{CRASH_ROUND} "
           f"round={ROUND_S * 1e3:g}ms")
    return [
        ("recovery.replay_ms", replay_ms, f"{pin}, simulated"),
        ("recovery.lost_requests", float(lost),
         f"{pin} gated at 0 by in-bench assert, simulated"),
    ]


def _preempt_ttft_rows(cfg, params) -> list[tuple]:
    from repro.serve.engine import Request
    from repro.serve.frontend import (
        FirstToken, FrontendConfig, Priority, StreamingFrontend,
        VirtualClock)
    from repro.serve.scheduler import SchedulerConfig

    rng = np.random.RandomState(1)
    hogs = [Request(tokens=rng.randint(0, cfg.vocab, 8),
                    max_new_tokens=12) for _ in range(2)]
    inters = [Request(tokens=rng.randint(0, cfg.vocab, 8),
                      max_new_tokens=4) for _ in range(N_INTER)]
    clock = VirtualClock()
    fe = StreamingFrontend(
        cfg, params,
        frontend=FrontendConfig(max_queue=8, feed_depth=1,
                                preempt_wait_ms=0.0),
        sched=SchedulerConfig(buckets=(8, 16), max_slots=2,
                              prefill_group=1, chunk=2, preempt=True),
        max_len=32, seed=0, clock=clock)
    for h in hogs:
        fe.submit(h, Priority.BEST_EFFORT)
    while fe.sched._free_slots() and fe.has_work():
        clock.now += ROUND_S
        fe.step()
    born = {}
    for q in inters:                # arrive against a saturated pool
        rid = fe.submit(q, Priority.INTERACTIVE)
        born[rid] = clock.now
    _drive(fe, clock)
    ttft = np.asarray([(ev.t - born[ev.rid]) * 1e3 for ev in fe.events
                       if isinstance(ev, FirstToken) and ev.rid in born])
    assert len(ttft) == N_INTER, "an interactive stream never started"
    pin = (f"{len(hogs)} hogs + {N_INTER} interactive preempt "
           f"maxq=8 round={ROUND_S * 1e3:g}ms")
    return [
        ("stream.preempt_ttft_p99_ms", pctl(ttft, 99),
         f"{pin} interactive, simulated"),
    ]


def recovery_rows() -> list[tuple]:
    from repro.configs import get_config
    from repro.models import backbone as bb

    cfg = get_config("qwen2-0.5b").reduced()
    params = bb.init_params(cfg, KEY)
    return _crash_replay_rows(cfg, params) + _preempt_ttft_rows(cfg, params)
